//! Structured span tracing — strictly side-band.
//!
//! A process-global span recorder the whole stack reports into: flow
//! task/edge execution, search rounds, surrogate fit/predict, the
//! worker-pool probe lifecycle (queue-wait vs execute), cache-tier
//! lookups, and (opt-in) interpreter kernels.  Three properties shape
//! the design:
//!
//! * **Near-zero overhead when disabled.**  Every entry point starts
//!   with one relaxed load of an `AtomicBool`; a disabled [`Span`] is
//!   `None` all the way down — no clock read, no allocation, no lock.
//! * **Deterministic identity, wall-clock side-notes.**  Span ids are
//!   position-in-parent paths (`"0/2/1"` = second child of the third
//!   child of root 0), assigned either from the opening thread's span
//!   stack or — for work fanned out across the pool — from an explicit
//!   logical slot the *submitter* chose ([`span_under`], [`BatchSpans`]).
//!   Wall-clock values appear only in `start_us`/`dur_us`/`tid`, which
//!   consumers strip when comparing structure.  Nothing here feeds back
//!   into search decisions, `ExecLog`s, or candidate sequences: the
//!   bit-identity contracts of the scheduler and cache layers hold with
//!   tracing on or off.
//! * **Thread-safe without a hot shared lock.**  Each thread appends to
//!   its own buffer (registered once with the global registry); buffers
//!   are merged and deterministically sorted at [`drain`] time.
//!
//! Export: [`chrome_trace`] renders the records as Chrome trace-event
//! JSON (loadable in Perfetto / `chrome://tracing`); [`summary_table`]
//! and [`cache_table`] aggregate a trace file back into per-stage /
//! per-tier breakdowns for `metaml trace summary`.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use crate::json::Value;
use crate::report::Table;
use crate::Result;

static ENABLED: AtomicBool = AtomicBool::new(false);
static KERNELS: AtomicBool = AtomicBool::new(false);

/// Process-global recorder state: the timestamp epoch, every thread's
/// buffer, and the root-span counter.
struct Registry {
    epoch: Option<Instant>,
    buffers: Vec<Arc<Mutex<Vec<SpanRecord>>>>,
    roots: usize,
    next_tid: u64,
}

static REGISTRY: Mutex<Registry> = Mutex::new(Registry {
    epoch: None,
    buffers: Vec::new(),
    roots: 0,
    next_tid: 1,
});

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// One closed span.  `id`/`parent`/`name`/`cat`/`args` are the
/// deterministic structure; `start_us`/`dur_us`/`tid` are wall-clock
/// side-notes.
#[derive(Debug, Clone)]
pub struct SpanRecord {
    /// Position-in-parent path, e.g. `"0/2/1"`.
    pub id: String,
    /// Parent path (`""` for roots).
    pub parent: String,
    pub name: String,
    /// Layer: `"flow"`, `"search"`, `"probe"`, `"cache"` or `"kernel"`.
    pub cat: &'static str,
    /// Microseconds since the recorder epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Ordinal of the recording thread (registration order).
    pub tid: u64,
    /// Rendered as a Chrome async begin/end pair instead of a complete
    /// event — for intervals that overlap sibling work on the recording
    /// thread (queue waits, batch envelopes).
    pub detached: bool,
    pub args: BTreeMap<String, Value>,
}

struct ThreadTrace {
    buf: Option<Arc<Mutex<Vec<SpanRecord>>>>,
    tid: u64,
    epoch: Option<Instant>,
    /// Open spans on this thread: (path, children allocated so far).
    stack: Vec<(String, usize)>,
}

thread_local! {
    static LOCAL: RefCell<ThreadTrace> = const {
        RefCell::new(ThreadTrace { buf: None, tid: 0, epoch: None, stack: Vec::new() })
    };
}

fn with_local<R>(f: impl FnOnce(&mut ThreadTrace) -> R) -> R {
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        if l.buf.is_none() {
            let mut reg = lock(&REGISTRY);
            let buf = Arc::new(Mutex::new(Vec::new()));
            reg.buffers.push(Arc::clone(&buf));
            l.tid = reg.next_tid;
            reg.next_tid += 1;
            l.epoch = reg.epoch;
            l.buf = Some(buf);
        }
        f(&mut l)
    })
}

fn epoch_of(l: &mut ThreadTrace) -> Instant {
    if let Some(e) = l.epoch {
        return e;
    }
    let mut reg = lock(&REGISTRY);
    let e = *reg.epoch.get_or_insert_with(Instant::now);
    l.epoch = Some(e);
    e
}

fn micros(epoch: Instant, t: Instant) -> u64 {
    t.saturating_duration_since(epoch).as_micros() as u64
}

fn push_record(l: &mut ThreadTrace, rec: SpanRecord) {
    if let Some(buf) = &l.buf {
        lock(buf).push(rec);
    }
}

/// Allocate the next child path under the innermost open span on this
/// thread (or a fresh root path).
fn alloc_path(l: &mut ThreadTrace) -> String {
    match l.stack.last_mut() {
        Some((parent, children)) => {
            let p = format!("{parent}/{children}");
            *children += 1;
            p
        }
        None => {
            let mut reg = lock(&REGISTRY);
            let idx = reg.roots;
            reg.roots += 1;
            idx.to_string()
        }
    }
}

fn parent_of(path: &str) -> &str {
    match path.rfind('/') {
        Some(i) => &path[..i],
        None => "",
    }
}

/// Turn tracing on (the epoch is fixed on first enable).
pub fn enable() {
    let mut reg = lock(&REGISTRY);
    if reg.epoch.is_none() {
        reg.epoch = Some(Instant::now());
    }
    drop(reg);
    ENABLED.store(true, Ordering::SeqCst);
}

pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Also record per-matmul kernel spans (high volume; opt-in via
/// `METAML_TRACE=kernels`).
pub fn enable_kernel_spans() {
    KERNELS.store(true, Ordering::SeqCst);
}

#[inline]
pub fn kernel_spans_enabled() -> bool {
    enabled() && KERNELS.load(Ordering::Relaxed)
}

/// Honour `METAML_TRACE`: any non-empty value other than `0` turns
/// tracing on; the value `kernels` additionally records kernel spans.
pub fn configure_from_env() {
    match std::env::var("METAML_TRACE") {
        Ok(v) if v == "kernels" => {
            enable();
            enable_kernel_spans();
        }
        Ok(v) if !v.is_empty() && v != "0" => enable(),
        _ => {}
    }
}

/// Drop every recorded span and restart root numbering.  Callers reset
/// *between* runs, never with spans still open.
pub fn reset() {
    let mut reg = lock(&REGISTRY);
    reg.roots = 0;
    if reg.epoch.is_none() {
        reg.epoch = Some(Instant::now());
    }
    for buf in &reg.buffers {
        lock(buf).clear();
    }
}

/// RAII guard for an open span.  When tracing is disabled this is a
/// single atomic load and an inert value.
#[derive(Debug)]
pub struct Span {
    inner: Option<OpenSpan>,
}

#[derive(Debug)]
struct OpenSpan {
    path: String,
    parent: String,
    name: String,
    cat: &'static str,
    start: Instant,
    args: BTreeMap<String, Value>,
}

impl Span {
    const INERT: Span = Span { inner: None };

    /// Attach an attribute (no-op when disabled).
    pub fn arg(&mut self, key: &str, val: impl Into<Value>) {
        if let Some(s) = &mut self.inner {
            s.args.insert(key.to_string(), val.into());
        }
    }

    /// Cloneable address of this span, for parenting work that runs on
    /// other threads at caller-chosen logical slots.
    pub fn handle(&self) -> SpanHandle {
        match &self.inner {
            Some(s) => SpanHandle { path: s.path.clone(), live: true },
            None => SpanHandle::default(),
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(open) = self.inner.take() else { return };
        let end = Instant::now();
        with_local(|l| {
            if l.stack.last().map(|(p, _)| p == &open.path).unwrap_or(false) {
                l.stack.pop();
            }
            let epoch = epoch_of(l);
            let rec = SpanRecord {
                id: open.path,
                parent: open.parent,
                name: open.name,
                cat: open.cat,
                start_us: micros(epoch, open.start),
                dur_us: end.saturating_duration_since(open.start).as_micros() as u64,
                tid: l.tid,
                detached: false,
                args: open.args,
            };
            push_record(l, rec);
        });
    }
}

/// Open a span as a child of the innermost open span on this thread
/// (or a new root).
pub fn span(cat: &'static str, name: impl Into<String>) -> Span {
    if !enabled() {
        return Span::INERT;
    }
    let name = name.into();
    with_local(|l| {
        let path = alloc_path(l);
        let parent = parent_of(&path).to_string();
        l.stack.push((path.clone(), 0));
        Span {
            inner: Some(OpenSpan {
                path,
                parent,
                name,
                cat,
                start: Instant::now(),
                args: BTreeMap::new(),
            }),
        }
    })
}

/// Open a `"kernel"`-layer span iff kernel spans are enabled (the
/// high-volume opt-in, `METAML_TRACE=kernels`); inert otherwise.
pub fn kernel_span(name: &'static str) -> Span {
    if !kernel_spans_enabled() {
        return Span::INERT;
    }
    span("kernel", name)
}

/// Addresses a span from another thread.  Inert handles (from a
/// disabled recorder) make every child operation a no-op.
#[derive(Debug, Clone, Default)]
pub struct SpanHandle {
    path: String,
    live: bool,
}

impl SpanHandle {
    pub fn live(&self) -> bool {
        self.live && enabled()
    }
}

/// Open a span at a fixed logical slot under `parent`.  The caller
/// assigns `index`, so the id is identical no matter which worker
/// thread runs the slot.  The span is pushed on the *current* thread's
/// stack: anything opened inside parents under it.
pub fn span_under(
    parent: &SpanHandle,
    index: usize,
    cat: &'static str,
    name: impl Into<String>,
) -> Span {
    if !parent.live() {
        return Span::INERT;
    }
    let path = format!("{}/{index}", parent.path);
    with_local(|l| {
        l.stack.push((path.clone(), 0));
        Span {
            inner: Some(OpenSpan {
                path,
                parent: parent.path.clone(),
                name: name.into(),
                cat,
                start: Instant::now(),
                args: BTreeMap::new(),
            }),
        }
    })
}

/// Record a closed interval at a fixed logical slot under `parent`
/// without touching any thread stack — for intervals that overlap
/// other spans on the recording thread (queue waits, cancel marks).
pub fn record_between(
    parent: &SpanHandle,
    index: usize,
    cat: &'static str,
    name: &str,
    from: Instant,
    to: Instant,
    args: &[(&str, Value)],
) {
    if !parent.live() {
        return;
    }
    let path = format!("{}/{index}", parent.path);
    with_local(|l| {
        let epoch = epoch_of(l);
        let rec = SpanRecord {
            id: path,
            parent: parent.path.clone(),
            name: name.to_string(),
            cat,
            start_us: micros(epoch, from),
            dur_us: to.saturating_duration_since(from).as_micros() as u64,
            tid: l.tid,
            detached: true,
            args: args.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
        };
        push_record(l, rec);
    });
}

/// Logical spans for one submitted probe batch: a detached batch
/// envelope opened on the submitting thread, plus per-slot children
/// attached from whichever thread runs each slot — wait at `2·i`,
/// execute at `2·i + 1`, so queue-wait and execute time are separate
/// spans with deterministic ids.  Both the worker-pool path and the
/// sequential inline path emit the same structure.
#[derive(Debug, Default)]
pub struct BatchSpans {
    inner: Option<BatchInner>,
}

#[derive(Debug)]
struct BatchInner {
    path: String,
    parent: String,
    n: usize,
    start: Instant,
    closed: AtomicBool,
}

/// Open a batch envelope as a child of the calling thread's innermost
/// span.  It is *not* pushed on the stack — children attach by slot.
pub fn batch(n: usize) -> BatchSpans {
    if !enabled() {
        return BatchSpans { inner: None };
    }
    with_local(|l| {
        let path = alloc_path(l);
        let parent = parent_of(&path).to_string();
        BatchSpans {
            inner: Some(BatchInner {
                path,
                parent,
                n,
                start: Instant::now(),
                closed: AtomicBool::new(false),
            }),
        }
    })
}

impl BatchSpans {
    pub fn handle(&self) -> SpanHandle {
        match &self.inner {
            Some(b) => SpanHandle { path: b.path.clone(), live: true },
            None => SpanHandle::default(),
        }
    }

    /// Slot `i` left the queue: record its wait interval (submit time →
    /// now).
    pub fn probe_claimed(&self, i: usize) {
        let Some(b) = &self.inner else { return };
        record_between(&self.handle(), 2 * i, "probe", "probe.wait", b.start, Instant::now(), &[]);
    }

    /// Guard span for slot `i`'s execution on the current thread.
    pub fn probe_span(&self, i: usize) -> Span {
        span_under(&self.handle(), 2 * i + 1, "probe", "probe.exec")
    }

    /// Emit the batch envelope record (idempotent; callable from any
    /// thread).
    pub fn close(&self) {
        self.finish(false);
    }

    /// Close the envelope for a batch whose unclaimed slots were
    /// cancelled.
    pub fn close_cancelled(&self) {
        self.finish(true);
    }

    fn finish(&self, cancelled: bool) {
        let Some(b) = &self.inner else { return };
        if !enabled() || b.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        let end = Instant::now();
        with_local(|l| {
            let epoch = epoch_of(l);
            let mut args = BTreeMap::new();
            args.insert("n".to_string(), Value::from(b.n));
            if cancelled {
                args.insert("cancelled".to_string(), Value::Bool(true));
            }
            push_record(
                l,
                SpanRecord {
                    id: b.path.clone(),
                    parent: b.parent.clone(),
                    name: "probe.batch".to_string(),
                    cat: "probe",
                    start_us: micros(epoch, b.start),
                    dur_us: end.saturating_duration_since(b.start).as_micros() as u64,
                    tid: l.tid,
                    detached: true,
                    args,
                },
            );
        });
    }
}

impl Drop for BatchSpans {
    fn drop(&mut self) {
        self.close();
    }
}

fn sort_records(recs: &mut [SpanRecord]) {
    recs.sort_by_cached_key(|r| {
        let key: Vec<u64> = r.id.split('/').filter_map(|s| s.parse::<u64>().ok()).collect();
        (key, r.name.clone())
    });
}

/// Move every recorded span out of the per-thread buffers, sorted by
/// id path (numeric segment order), then name.
pub fn drain() -> Vec<SpanRecord> {
    let reg = lock(&REGISTRY);
    let mut out = Vec::new();
    for buf in &reg.buffers {
        out.append(&mut lock(buf));
    }
    drop(reg);
    sort_records(&mut out);
    out
}

/// Copy of the recorded spans without clearing them.
pub fn snapshot() -> Vec<SpanRecord> {
    let reg = lock(&REGISTRY);
    let mut out = Vec::new();
    for buf in &reg.buffers {
        out.extend(lock(buf).iter().cloned());
    }
    drop(reg);
    sort_records(&mut out);
    out
}

/// Render spans as Chrome trace-event JSON (`chrome://tracing` and
/// Perfetto both load it).  Stack-nested spans become complete (`"X"`)
/// events on their recording thread; detached intervals become async
/// (`"b"`/`"e"`) pairs keyed by span id, so overlapping queue waits do
/// not fight the per-thread slice stack.  The logical id/parent ride in
/// `args.span`/`args.parent`.
pub fn chrome_trace(spans: &[SpanRecord]) -> Value {
    let mut events = Vec::new();
    for s in spans {
        if s.detached {
            events.push(chrome_event(s, "b", s.start_us, false));
            events.push(chrome_event(s, "e", s.start_us + s.dur_us, false));
        } else {
            events.push(chrome_event(s, "X", s.start_us, true));
        }
    }
    let mut root = Value::object();
    root.set("traceEvents", Value::Array(events));
    root.set("displayTimeUnit", "ms");
    root
}

fn chrome_event(s: &SpanRecord, ph: &str, ts: u64, with_dur: bool) -> Value {
    let mut e = Value::object();
    e.set("name", s.name.as_str());
    e.set("cat", s.cat);
    e.set("ph", ph);
    e.set("ts", ts as f64);
    if with_dur {
        e.set("dur", s.dur_us as f64);
    }
    e.set("pid", 1u64);
    e.set("tid", s.tid);
    if ph != "X" {
        e.set("id", s.id.as_str());
    }
    let mut args = Value::object();
    args.set("span", s.id.as_str());
    args.set("parent", s.parent.as_str());
    for (k, v) in &s.args {
        args.set(k, v.clone());
    }
    e.set("args", args);
    e
}

/// Aggregate a Chrome trace (as emitted by [`chrome_trace`]) into a
/// per-span-name breakdown: count, total and mean wall time.  Async
/// pairs are matched by `(name, id)`.
pub fn summary_table(doc: &Value) -> Result<Table> {
    let events = doc.req_array("traceEvents")?;
    // name -> (cat, count, total_us)
    let mut stages: BTreeMap<String, (String, u64, u64)> = BTreeMap::new();
    let mut open: BTreeMap<(String, String), f64> = BTreeMap::new();
    for e in events {
        let name = e.req_str("name")?.to_string();
        let cat = e.get("cat").and_then(Value::as_str).unwrap_or("").to_string();
        let ph = e.req_str("ph")?;
        let ts = e.req_f64("ts")?;
        match ph {
            "X" => {
                let dur = e.get("dur").and_then(Value::as_f64).unwrap_or(0.0);
                let entry = stages.entry(name).or_insert((cat, 0, 0));
                entry.1 += 1;
                entry.2 += dur.max(0.0) as u64;
            }
            "b" => {
                if let Some(id) = e.get("id").and_then(Value::as_str) {
                    open.insert((name, id.to_string()), ts);
                }
            }
            "e" => {
                if let Some(id) = e.get("id").and_then(Value::as_str) {
                    if let Some(t0) = open.remove(&(name.clone(), id.to_string())) {
                        let entry = stages.entry(name).or_insert((cat, 0, 0));
                        entry.1 += 1;
                        entry.2 += (ts - t0).max(0.0) as u64;
                    }
                }
            }
            _ => {}
        }
    }
    let mut table = Table::new(&["span", "layer", "count", "total ms", "mean ms"]);
    for (name, (cat, count, total_us)) in &stages {
        let total_ms = *total_us as f64 / 1000.0;
        table.row(&[
            name.clone(),
            cat.clone(),
            count.to_string(),
            format!("{total_ms:.3}"),
            format!("{:.3}", total_ms / (*count).max(1) as f64),
        ]);
    }
    Ok(table)
}

/// Aggregate the `cache.lookup` spans of a Chrome trace into a
/// per-(probe kind, tier) hit/miss table, or `None` when the trace has
/// no cache lookups.
pub fn cache_table(doc: &Value) -> Result<Option<Table>> {
    let events = doc.req_array("traceEvents")?;
    let mut tiers: BTreeMap<(String, String), (u64, u64)> = BTreeMap::new();
    for e in events {
        if e.get("name").and_then(Value::as_str) != Some("cache.lookup")
            || e.get("ph").and_then(Value::as_str) != Some("X")
        {
            continue;
        }
        let Some(args) = e.get("args") else { continue };
        let tier = args.get("tier").and_then(Value::as_str).unwrap_or("?").to_string();
        let kind = args.get("kind").and_then(Value::as_str).unwrap_or("?").to_string();
        let hits = args.get("hits").and_then(Value::as_f64).unwrap_or(0.0) as u64;
        let misses = args.get("misses").and_then(Value::as_f64).unwrap_or(0.0) as u64;
        let entry = tiers.entry((kind, tier)).or_insert((0, 0));
        entry.0 += hits;
        entry.1 += misses;
    }
    if tiers.is_empty() {
        return Ok(None);
    }
    let mut table = Table::new(&["probe kind", "tier", "hits", "misses", "hit rate"]);
    for ((kind, tier), (hits, misses)) in &tiers {
        let total = hits + misses;
        let rate = if total == 0 {
            "-".to_string()
        } else {
            format!("{:.4}", *hits as f64 / total as f64)
        };
        table.row(&[kind.clone(), tier.clone(), hits.to_string(), misses.to_string(), rate]);
    }
    Ok(Some(table))
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is process-global and other lib tests run
    // concurrently in this process, so: serialize the tests that
    // enable tracing on a gate, give each a uniquely named root, and
    // assert only on that root's subtree (foreign spans recorded while
    // the gate holder had tracing on are filtered out, not raced on).
    static GATE: Mutex<()> = Mutex::new(());

    /// Drain, keep the subtree under the (unique) `root_name` span, and
    /// rewrite ids relative to that root (its id becomes "r").
    fn subtree(root_name: &str) -> Vec<SpanRecord> {
        let spans = drain();
        let root_id = spans
            .iter()
            .find(|s| s.name == root_name)
            .unwrap_or_else(|| panic!("root span {root_name} not recorded"))
            .id
            .clone();
        let prefix = format!("{root_id}/");
        spans
            .into_iter()
            .filter(|s| s.id == root_id || s.id.starts_with(&prefix))
            .map(|mut s| {
                s.id = format!("r{}", &s.id[root_id.len()..]);
                s.parent = if s.parent.len() < root_id.len() {
                    String::new()
                } else {
                    format!("r{}", &s.parent[root_id.len()..])
                };
                s
            })
            .collect()
    }

    #[test]
    fn disabled_spans_are_inert() {
        // no gate needed: nothing here turns tracing on, and inertness
        // is visible on the values themselves
        let mut s = Span::INERT;
        s.arg("k", 1u64);
        assert!(!s.handle().live());
        let b = BatchSpans::default();
        b.probe_claimed(0);
        assert!(b.probe_span(0).inner.is_none());
        assert!(!b.handle().live());
        b.close();
    }

    #[test]
    fn positional_ids_nest_and_sort() {
        let _g = lock(&GATE);
        enable();
        {
            let root = span("search", "obs-test-nest-root");
            let h = root.handle();
            {
                let _a = span("search", "a");
                let _leaf = span("search", "a0");
            }
            let _b = span_under(&h, 7, "probe", "slot7");
        }
        disable();
        let ids: Vec<(String, String, String)> = subtree("obs-test-nest-root")
            .iter()
            .map(|s| (s.id.clone(), s.parent.clone(), s.name.clone()))
            .collect();
        assert_eq!(
            ids,
            vec![
                ("r".into(), "".into(), "obs-test-nest-root".into()),
                ("r/0".into(), "r".into(), "a".into()),
                ("r/0/0".into(), "r/0".into(), "a0".into()),
                ("r/7".into(), "r".into(), "slot7".into()),
            ]
        );
    }

    #[test]
    fn batch_spans_emit_wait_exec_and_envelope() {
        let _g = lock(&GATE);
        enable();
        let root = span("search", "obs-test-batch-root");
        let b = batch(2);
        b.probe_claimed(0);
        drop(b.probe_span(0));
        b.probe_claimed(1);
        drop(b.probe_span(1));
        b.close();
        drop(root);
        disable();
        let spans = subtree("obs-test-batch-root");
        let names: Vec<&str> = spans.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "obs-test-batch-root",
                "probe.batch",
                "probe.wait",
                "probe.exec",
                "probe.wait",
                "probe.exec"
            ]
        );
        let envelope = &spans[1];
        assert!(envelope.detached);
        assert_eq!(envelope.args.get("n"), Some(&Value::from(2usize)));
    }

    #[test]
    fn chrome_trace_round_trips_through_summary() {
        let _g = lock(&GATE);
        enable();
        {
            let _root = span("search", "obs-test-chrome-root");
            let b = batch(1);
            b.probe_claimed(0);
            drop(b.probe_span(0));
            let mut c = span("cache", "cache.lookup");
            c.arg("tier", "memo");
            c.arg("kind", "train");
            c.arg("hits", 3u64);
            c.arg("misses", 1u64);
        }
        disable();
        let doc = chrome_trace(&subtree("obs-test-chrome-root"));
        let rendered = summary_table(&doc).unwrap().render();
        assert!(rendered.contains("probe.wait"));
        assert!(rendered.contains("probe.exec"));
        assert!(rendered.contains("probe.batch"));
        let cache = cache_table(&doc).unwrap().expect("cache rows");
        let rendered = cache.render();
        assert!(rendered.contains("memo"));
        assert!(rendered.contains("0.7500"));
    }
}
