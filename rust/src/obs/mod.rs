//! Observability: structured tracing + metrics, strictly side-band.
//!
//! Two halves with different lifecycles:
//!
//! * [`trace`] — span recording, **off by default** (one `AtomicBool`
//!   branch per would-be span when disabled).  Enabled by the CLI's
//!   `--trace-out` flag or the `METAML_TRACE` environment variable.
//! * [`metrics`] — counters / gauges / log-bucketed histograms,
//!   **always on**: the registry is where wall-clock accounting lives
//!   (`search.wall_secs`, per-tier `cache.*` counters, bridged
//!   `probes.*` totals), exported by `--metrics-out`.
//!
//! Determinism contract: nothing in this module feeds back into flow
//! execution, search decisions, `ExecLog` event streams, candidate
//! sequences or fronts.  Span *structure* (ids, names, parentage) is
//! deterministic — position-in-parent ids, caller-assigned slots for
//! pooled work — while timestamps, durations and thread ordinals are
//! wall-clock side-notes.  Probe/cache span *counts* track what was
//! actually issued, which (like `ProbeCounts::train_issued`) scales
//! with the worker configuration by design; flow/search-layer spans
//! are jobs-invariant under barrier scheduling.

pub mod metrics;
pub mod trace;
