//! Process-global metrics registry: counters, gauges and log-bucketed
//! histograms with a stable JSON snapshot schema.
//!
//! Unlike spans (see [`super::trace`]), the registry is always on — the
//! series it keeps (wall clock, cache tier hit/miss, bridged probe
//! totals) are coarse enough that a short critical section per update
//! is negligible next to the work being measured.  Values never feed
//! back into search decisions: the registry is the one place wall-clock
//! accounting lives (`SearchCost.wall_secs` and the explore summary
//! read it), keeping `Instant` plumbing out of the search driver.
//!
//! Snapshot schema (all maps sorted, all numbers JSON numbers):
//!
//! ```json
//! {
//!   "counters":   {"cache.train.memo.hit": 12, ...},
//!   "gauges":     {"search.wall_secs": 1.25, ...},
//!   "histograms": {"search.wall_secs.hist": {"count": 1, "sum": 1.25,
//!                                            "buckets": [0, ...]}, ...}
//! }
//! ```
//!
//! Histogram buckets are powers of two over microseconds: bucket `b`
//! counts observations in `[2^b, 2^(b+1))` µs (bucket 0 also absorbs
//! sub-microsecond values); trailing empty buckets are trimmed.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use crate::dse::ProbeCounts;
use crate::json::Value;

#[derive(Debug, Default, Clone)]
struct Hist {
    count: u64,
    sum: f64,
    buckets: Vec<u64>,
}

#[derive(Debug, Default)]
struct Store {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Hist>,
}

static STORE: Mutex<Option<Store>> = Mutex::new(None);

fn lock() -> MutexGuard<'static, Option<Store>> {
    STORE.lock().unwrap_or_else(|e| e.into_inner())
}

fn with_store<R>(f: impl FnOnce(&mut Store) -> R) -> R {
    let mut guard = lock();
    f(guard.get_or_insert_with(Store::default))
}

pub fn counter_add(name: &str, delta: u64) {
    with_store(|s| *s.counters.entry(name.to_string()).or_insert(0) += delta);
}

/// Overwrite a counter with an externally accumulated total (used by
/// the [`ProbeCounts`] bridge, whose atomics are the source of truth).
pub fn counter_set(name: &str, value: u64) {
    with_store(|s| {
        s.counters.insert(name.to_string(), value);
    });
}

pub fn counter(name: &str) -> u64 {
    with_store(|s| s.counters.get(name).copied().unwrap_or(0))
}

pub fn gauge_set(name: &str, value: f64) {
    with_store(|s| {
        s.gauges.insert(name.to_string(), value);
    });
}

pub fn gauge(name: &str) -> Option<f64> {
    with_store(|s| s.gauges.get(name).copied())
}

/// Record one observation into the log-bucketed histogram `name`.
pub fn observe_secs(name: &str, secs: f64) {
    let us = (secs.max(0.0) * 1e6) as u64;
    let bucket = (63 - us.max(1).leading_zeros()) as usize;
    with_store(|s| {
        let h = s.hists.entry(name.to_string()).or_default();
        h.count += 1;
        h.sum += secs;
        if h.buckets.len() <= bucket {
            h.buckets.resize(bucket + 1, 0);
        }
        h.buckets[bucket] += 1;
    });
}

/// A named wall-clock timer.  [`Stopwatch::stop`] records the elapsed
/// seconds into the registry (gauge `<name>` + histogram `<name>.hist`)
/// and returns them, so the caller keeps a race-free local value while
/// the registry carries the latest reading.
#[derive(Debug)]
pub struct Stopwatch {
    name: String,
    start: Instant,
}

pub fn start_timer(name: &str) -> Stopwatch {
    Stopwatch { name: name.to_string(), start: Instant::now() }
}

impl Stopwatch {
    pub fn stop(self) -> f64 {
        let secs = self.start.elapsed().as_secs_f64();
        gauge_set(&self.name, secs);
        observe_secs(&format!("{}.hist", self.name), secs);
        secs
    }
}

/// Mirror a [`ProbeCounts`] snapshot into `probes.*` counters.  The
/// shared `ProbeStats` atomics stay the one source of truth; the
/// registry carries their latest totals for export.
pub fn bridge_probe_counts(c: &ProbeCounts) {
    counter_set("probes.train.issued", c.train_issued as u64);
    counter_set("probes.train.computed", c.train_computed as u64);
    counter_set("probes.hw.issued", c.hw_issued as u64);
    counter_set("probes.hw.computed", c.hw_computed as u64);
    counter_set("probes.surrogate.fits", c.sur_fits as u64);
    counter_set("probes.surrogate.predictions", c.sur_predictions as u64);
    counter_set("probes.speculation.submitted", c.spec_submitted as u64);
    counter_set("probes.speculation.committed", c.spec_committed as u64);
    counter_set("probes.speculation.cancelled", c.spec_cancelled as u64);
}

/// Stable JSON snapshot of every series.
pub fn snapshot() -> Value {
    with_store(|s| {
        let mut counters = Value::object();
        for (k, v) in &s.counters {
            counters.set(k, *v);
        }
        let mut gauges = Value::object();
        for (k, v) in &s.gauges {
            gauges.set(k, *v);
        }
        let mut hists = Value::object();
        for (k, h) in &s.hists {
            let mut o = Value::object();
            o.set("count", h.count);
            o.set("sum", h.sum);
            o.set(
                "buckets",
                Value::Array(h.buckets.iter().map(|b| Value::from(*b)).collect()),
            );
            hists.set(k, o);
        }
        let mut root = Value::object();
        root.set("counters", counters);
        root.set("gauges", gauges);
        root.set("histograms", hists);
        root
    })
}

/// Clear every series (tests, and the CLI before an exported run).
pub fn reset() {
    with_store(|s| {
        s.counters.clear();
        s.gauges.clear();
        s.hists.clear();
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    // Series names here are test-unique: the registry is process-global
    // and other lib tests run concurrently.
    #[test]
    fn counters_gauges_and_histograms_round_trip() {
        counter_add("obs-test.counter", 2);
        counter_add("obs-test.counter", 3);
        assert_eq!(counter("obs-test.counter"), 5);
        counter_set("obs-test.counter", 7);
        assert_eq!(counter("obs-test.counter"), 7);

        gauge_set("obs-test.gauge", 1.5);
        assert_eq!(gauge("obs-test.gauge"), Some(1.5));
        assert_eq!(gauge("obs-test.missing"), None);

        observe_secs("obs-test.hist", 3e-6); // bucket 1: [2, 4) µs
        observe_secs("obs-test.hist", 3e-6);
        observe_secs("obs-test.hist", 0.0); // bucket 0
        let snap = snapshot();
        let h = snap.get("histograms").and_then(|v| v.get("obs-test.hist")).unwrap();
        assert_eq!(h.get("count").and_then(Value::as_usize), Some(3));
        let buckets = h.get("buckets").and_then(Value::as_array).unwrap();
        assert_eq!(buckets[0].as_usize(), Some(1));
        assert_eq!(buckets[1].as_usize(), Some(2));
    }

    #[test]
    fn stopwatch_records_gauge_and_histogram() {
        let sw = start_timer("obs-test.sw");
        let secs = sw.stop();
        assert!(secs >= 0.0);
        assert_eq!(gauge("obs-test.sw"), Some(secs));
        let snap = snapshot();
        let h = snap.get("histograms").and_then(|v| v.get("obs-test.sw.hist")).unwrap();
        assert!(h.get("count").and_then(Value::as_usize).unwrap_or(0) >= 1);
    }

    #[test]
    fn probe_counts_bridge_sets_totals() {
        let c = ProbeCounts { train_issued: 4, train_computed: 3, ..Default::default() };
        bridge_probe_counts(&c);
        assert_eq!(counter("probes.train.issued"), 4);
        assert_eq!(counter("probes.train.computed"), 3);
    }
}
