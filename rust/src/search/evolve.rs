//! `Evolve`: NSGA-II-style multi-objective evolutionary search.
//!
//! The adaptive strategy: maintain an archive of every observed
//! (candidate, objectives) pair, rank it by non-dominated sorting +
//! crowding distance (the shared [`crate::search::pareto`] kernel), and
//! spend the budget where the front is.
//!
//! Per generation:
//!
//! 1. **Seeding** (first call): enumerate the whole discrete grid when
//!    it is small (≤ [`GEN0_ENUM_CAP`] points; range dimensions get
//!    seeded uniform values), else draw a uniform pool of `4 × P`
//!    points.  The pool is then *ordered* — by the context's
//!    [`crate::search::CandidateRanker`] when one is available (the
//!    cheap-estimator prefilter's hardware-only NSGA rank through
//!    [`crate::dse::ProbePool::estimate_batch`]/`HwCache`, or the
//!    fitted surrogate's predicted NSGA rank, so no training probe is
//!    spent learning what a cheap model already knows), otherwise by a
//!    seeded shuffle — and the first `min(P, budget left)` points
//!    become generation 0.
//! 2. **Evolution**: binary-tournament parent selection on (rank,
//!    crowding), uniform per-dimension crossover, mutation with
//!    probability `1/n_dims` per dimension (categorical dims resample
//!    uniformly; range dims take a Gaussian step of σ = 20% of the
//!    interval, snapped back in).  Offspring repeating an evaluated or
//!    in-batch point are rejected and regenerated (bounded tries).
//!    With the prefilter on, twice the needed offspring are generated
//!    and the estimator-ranked best half survive.
//! 3. **Exhaustion fallback**: when evolution cannot produce a novel
//!    point (tiny grids late in the run), the first still-unevaluated
//!    grid points in enumeration order are proposed instead; if none
//!    remain and there are no range dimensions, the strategy returns an
//!    empty batch and the search ends early — so `evolve` with budget ≥
//!    grid size degenerates to full coverage, never an infinite loop.
//!
//! Everything is driven by the run's seeded [`Prng`] and the
//! deterministic observation stream, so a fixed (spec, seed, budget)
//! reproduces the exact candidate sequence for any worker count.

use std::collections::{HashMap, HashSet};

use crate::error::Result;
use crate::search::driver::{Observation, SearchCtx, SearchStrategy};
use crate::search::pareto::nsga_order;
use crate::search::space::{Candidate, CandidateKey, SearchSpace};
use crate::util::prng::Prng;

/// Grid sizes up to this are fully enumerated for the seeding pool.
pub const GEN0_ENUM_CAP: usize = 256;
/// Default population (overridable via the spec's
/// `search.population`).
pub const DEFAULT_POPULATION: usize = 8;
/// Offspring-generation attempts per needed novel candidate.
const TRIES_PER_OFFSPRING: usize = 16;

pub struct Evolve {
    prng: Prng,
    population: usize,
    /// Every observed point: (candidate, minimization objectives).
    /// Surrogate-predicted observations are archived too (they steer
    /// evolution away from dominated regions) and upgraded in place
    /// when a re-validation delivers the truth.
    archive: Vec<(Candidate, Vec<f64>)>,
    /// Key → (archive slot, objectives are still predicted).
    archive_keys: HashMap<CandidateKey, (usize, bool)>,
}

impl Evolve {
    pub fn new(seed: u64, population: Option<usize>) -> Self {
        Evolve {
            prng: Prng::new(seed),
            population: population.unwrap_or(DEFAULT_POPULATION).max(2),
            archive: Vec::new(),
            archive_keys: HashMap::new(),
        }
    }

    /// Order a candidate pool best-first: ranker order when available
    /// (hardware prefilter or fitted surrogate, falling back on
    /// estimator errors), else a seeded shuffle.
    fn order_pool(&mut self, ctx: &SearchCtx<'_>, pool: Vec<Candidate>) -> Vec<Candidate> {
        if let Some(rk) = ctx.ranker {
            if let Ok(order) = rk.rank(ctx.space, &pool) {
                return order.into_iter().map(|i| pool[i].clone()).collect();
            }
        }
        let mut shuffled = pool;
        self.prng.shuffle(&mut shuffled);
        shuffled
    }

    /// Generation-0 candidate pool over the joint space.
    fn seed_pool(&mut self, space: &SearchSpace) -> Vec<Candidate> {
        let n = space.grid_size();
        if n <= GEN0_ENUM_CAP {
            return (0..n).map(|i| space.nth_grid_point(i, &mut self.prng)).collect();
        }
        let want = 4 * self.population;
        let mut seen = HashSet::new();
        let mut pool = Vec::new();
        let mut tries = want * TRIES_PER_OFFSPRING;
        while pool.len() < want && tries > 0 {
            tries -= 1;
            let c = space.sample(&mut self.prng);
            if seen.insert(space.key(&c)) {
                pool.push(c);
            }
        }
        pool
    }

    /// Binary tournament on the NSGA survivor ordering: the parent at
    /// the better (smaller) position wins.
    fn tournament(&mut self, positions: &[usize]) -> usize {
        let a = self.prng.below(positions.len());
        let b = self.prng.below(positions.len());
        if positions[a] <= positions[b] {
            a
        } else {
            b
        }
    }

    /// Uniform crossover + per-dimension mutation.
    fn offspring(&mut self, space: &SearchSpace, pa: &Candidate, pb: &Candidate) -> Candidate {
        let pick = |prng: &mut Prng| prng.below(2) == 0;
        let mut child = Candidate {
            order: if pick(&mut self.prng) { pa.order } else { pb.order },
            grid: pa
                .grid
                .iter()
                .zip(&pb.grid)
                .map(|(&a, &b)| if pick(&mut self.prng) { a } else { b })
                .collect(),
            range: pa
                .range
                .iter()
                .zip(&pb.range)
                .map(|(&a, &b)| if pick(&mut self.prng) { a } else { b })
                .collect(),
        };
        let n_dims = space.n_dims() as f64;
        if self.prng.uniform() < 1.0 / n_dims {
            child.order = self.prng.below(space.orders.len());
        }
        for (i, (_, vals)) in space.grid.iter().enumerate() {
            if self.prng.uniform() < 1.0 / n_dims {
                child.grid[i] = self.prng.below(vals.len());
            }
        }
        for (i, (_, dim)) in space.ranges.iter().enumerate() {
            if self.prng.uniform() < 1.0 / n_dims {
                let step = self.prng.normal() * 0.2 * (dim.hi - dim.lo);
                child.range[i] = dim.snap(child.range[i] + step);
            }
        }
        child
    }

    /// First still-unevaluated grid points in enumeration order (the
    /// deterministic fallback when evolution goes dry).
    fn unevaluated_sweep(&mut self, ctx: &SearchCtx<'_>, want: usize) -> Vec<Candidate> {
        let mut out = Vec::new();
        for i in 0..ctx.space.grid_size() {
            if out.len() >= want {
                break;
            }
            let c = ctx.space.nth_grid_point(i, &mut self.prng);
            let key = ctx.space.key(&c);
            if !ctx.evaluated.contains_key(&key) && !ctx.deferred.contains_key(&key) {
                out.push(c);
            }
        }
        out
    }
}

impl SearchStrategy for Evolve {
    fn name(&self) -> &'static str {
        "evolve"
    }

    fn propose(&mut self, ctx: &SearchCtx<'_>, limit: usize) -> Result<Vec<Candidate>> {
        let want = self.population.min(limit);
        if want == 0 {
            return Ok(Vec::new());
        }

        if self.archive.is_empty() {
            let pool = self.seed_pool(ctx.space);
            let ordered = self.order_pool(ctx, pool);
            return Ok(ordered
                .into_iter()
                .filter(|c| {
                    let key = ctx.space.key(c);
                    !ctx.evaluated.contains_key(&key) && !ctx.deferred.contains_key(&key)
                })
                .take(want)
                .collect());
        }

        // parent ordering: position in the NSGA survivor order
        let objectives: Vec<Vec<f64>> =
            self.archive.iter().map(|(_, o)| o.clone()).collect();
        let order = nsga_order(&objectives);
        let mut positions = vec![0usize; self.archive.len()];
        for (pos, &i) in order.iter().enumerate() {
            positions[i] = pos;
        }

        // generate novel offspring (surplus ×2 when a ranker can rank
        // the extras away)
        let surplus = if ctx.ranker.is_some() { 2 * want } else { want };
        let mut taken: HashSet<CandidateKey> = HashSet::new();
        let mut pool = Vec::new();
        let mut tries = surplus * TRIES_PER_OFFSPRING;
        while pool.len() < surplus && tries > 0 {
            tries -= 1;
            let pa = self.tournament(&positions);
            let pb = self.tournament(&positions);
            let (pa, pb) = (self.archive[pa].0.clone(), self.archive[pb].0.clone());
            let child = self.offspring(ctx.space, &pa, &pb);
            let key = ctx.space.key(&child);
            if !ctx.evaluated.contains_key(&key)
                && !ctx.deferred.contains_key(&key)
                && !taken.contains(&key)
            {
                taken.insert(key);
                pool.push(child);
            }
        }
        if pool.is_empty() {
            // evolution is dry (taken is empty too): cover what's left
            // of the grid instead
            return Ok(self.unevaluated_sweep(ctx, want));
        }
        let ordered = self.order_pool(ctx, pool);
        Ok(ordered.into_iter().take(want).collect())
    }

    fn speculate(&self, ctx: &SearchCtx<'_>) -> Vec<Candidate> {
        // Clone the PRNG and archive state and run a full-population
        // propose on the clone: `observe` consumes no randomness, so
        // the clone's generator sits exactly where the real `propose`
        // will start — its guess *set* contains the real next batch
        // whenever the real batch is at most a population wide (the
        // real call may draw fewer offspring when the budget runs
        // short, which only reorders the shared prefix).  The ranker
        // is withheld (`ranker: None`): speculation must not spend
        // counted prefilter/surrogate queries.
        let mut probe = Evolve {
            prng: self.prng.clone(),
            population: self.population,
            archive: self.archive.clone(),
            archive_keys: self.archive_keys.clone(),
        };
        let ctx = SearchCtx {
            space: ctx.space,
            evaluated: ctx.evaluated,
            deferred: ctx.deferred,
            ranker: None,
        };
        probe.propose(&ctx, self.population).unwrap_or_default()
    }

    fn observe(&mut self, ctx: &SearchCtx<'_>, batch: &[Observation]) {
        for obs in batch {
            let key = ctx.space.key(&obs.candidate);
            match self.archive_keys.get(&key) {
                None => {
                    self.archive_keys.insert(key, (self.archive.len(), obs.predicted));
                    self.archive.push((obs.candidate.clone(), obs.objectives.clone()));
                }
                // a re-validated deferral upgrades its predicted
                // archive entry to the truth, in place
                Some(&(slot, true)) if !obs.predicted => {
                    self.archive[slot].1 = obs.objectives.clone();
                    self.archive_keys.insert(key, (slot, false));
                }
                Some(_) => {}
            }
        }
    }
}
