//! N-objective dominance kernel.
//!
//! One implementation of Pareto dominance serves every consumer: the
//! multi-flow explorer's (accuracy, DSP, LUT, latency) front, the
//! NSGA-II-style [`crate::search::Evolve`] strategy (non-dominated
//! sorting + crowding distance), the hardware-only prefilter ranking,
//! and the bench harness's hypervolume trajectory.  All functions take
//! **minimization** objective vectors — callers negate
//! maximized metrics (accuracy) before handing points in, which keeps
//! the kernel free of per-objective sense flags and lets new objectives
//! (power_w, …) join by just extending the vector.
//!
//! Every routine is deterministic: indices come back ascending (or in a
//! documented stable order), so search traces built on top compare
//! bit-for-bit across runs and worker counts.

/// Does `a` dominate `b` (minimization)?  True when `a` is no worse on
/// every objective and strictly better on at least one.  Vectors of
/// different lengths never dominate each other (callers mixing
/// objective spaces is a bug this turns into a harmless "no").
pub fn dominates_min(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| x <= y)
        && a.iter().zip(b).any(|(x, y)| x < y)
}

/// Non-dominated set over minimization objective vectors, as ascending
/// indices.  Exact duplicates do not dominate each other, so ties are
/// all kept (the explorer relies on this to surface equivalent design
/// points).
pub fn pareto_front_min(points: &[Vec<f64>]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            !points
                .iter()
                .enumerate()
                .any(|(j, p)| j != i && dominates_min(p, &points[i]))
        })
        .collect()
}

/// NSGA-II non-dominated sorting: rank 0 is the Pareto front, rank 1
/// the front after removing rank 0, and so on.  Returns one rank per
/// point.
pub fn non_dominated_rank(points: &[Vec<f64>]) -> Vec<usize> {
    let n = points.len();
    let mut rank = vec![usize::MAX; n];
    let mut assigned = 0usize;
    let mut level = 0usize;
    while assigned < n {
        let mut this_level = Vec::new();
        for i in 0..n {
            if rank[i] != usize::MAX {
                continue;
            }
            let dominated = (0..n).any(|j| {
                j != i && rank[j] == usize::MAX && dominates_min(&points[j], &points[i])
            });
            if !dominated {
                this_level.push(i);
            }
        }
        // ties among identical points land in the same level together,
        // so the peel always makes progress
        for &i in &this_level {
            rank[i] = level;
            assigned += 1;
        }
        level += 1;
    }
    rank
}

/// NSGA-II crowding distance over one front (all points assumed to be
/// mutually non-dominated, though the formula doesn't require it).
/// Boundary points per objective get `f64::INFINITY`; interior points
/// accumulate the normalized neighbour gap.  Larger = lonelier =
/// preferred when truncating a front.
pub fn crowding_distances(points: &[Vec<f64>]) -> Vec<f64> {
    let n = points.len();
    if n == 0 {
        return Vec::new();
    }
    let m = points[0].len();
    if n <= 2 {
        return vec![f64::INFINITY; n];
    }
    let mut dist = vec![0.0f64; n];
    for obj in 0..m {
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| points[a][obj].total_cmp(&points[b][obj]));
        let lo = points[order[0]][obj];
        let hi = points[order[n - 1]][obj];
        if hi <= lo {
            // degenerate objective: no spread, no boundaries to reward —
            // skipping it entirely keeps fully-tied groups at distance 0,
            // so downstream orderings fall back to their index tie-break
            continue;
        }
        dist[order[0]] = f64::INFINITY;
        dist[order[n - 1]] = f64::INFINITY;
        for w in 1..n - 1 {
            let gap = points[order[w + 1]][obj] - points[order[w - 1]][obj];
            dist[order[w]] += gap / (hi - lo);
        }
    }
    dist
}

/// Order point indices best-first by (non-dominated rank ascending,
/// crowding distance descending, index ascending).  The standard
/// NSGA-II survivor ordering, reused by the hardware prefilter to rank
/// candidate batches on cheap estimator objectives.
pub fn nsga_order(points: &[Vec<f64>]) -> Vec<usize> {
    let ranks = non_dominated_rank(points);
    let mut crowd = vec![0.0f64; points.len()];
    let n_levels = ranks.iter().copied().max().map(|r| r + 1).unwrap_or(0);
    for level in 0..n_levels {
        let members: Vec<usize> =
            (0..points.len()).filter(|&i| ranks[i] == level).collect();
        let level_points: Vec<Vec<f64>> =
            members.iter().map(|&i| points[i].clone()).collect();
        let level_crowd = crowding_distances(&level_points);
        for (slot, &i) in members.iter().enumerate() {
            crowd[i] = level_crowd[slot];
        }
    }
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&a, &b| {
        ranks[a]
            .cmp(&ranks[b])
            .then(crowd[b].total_cmp(&crowd[a]))
            .then(a.cmp(&b))
    });
    order
}

/// How many points the hypervolume routine handles exactly (the
/// inclusion–exclusion sum is `2^front`); larger fronts keep only the
/// first `HYPERVOLUME_EXACT_CAP` non-dominated points, which
/// under-reports — callers wanting the exact number should shrink the
/// front first.
pub const HYPERVOLUME_EXACT_CAP: usize = 16;

/// Hypervolume (minimization) of the region dominated by `points`
/// relative to `reference` — the standard front-quality scalar the
/// bench trajectory tracks.  Points not strictly better than the
/// reference on some objective contribute nothing.  Exact via
/// inclusion–exclusion over the non-dominated subset (capped at
/// [`HYPERVOLUME_EXACT_CAP`] points).
pub fn hypervolume(points: &[Vec<f64>], reference: &[f64]) -> f64 {
    let mut front: Vec<&Vec<f64>> = pareto_front_min(points)
        .into_iter()
        .map(|i| &points[i])
        .filter(|p| p.len() == reference.len() && p.iter().zip(reference).all(|(x, r)| x < r))
        .collect();
    front.truncate(HYPERVOLUME_EXACT_CAP);
    let n = front.len();
    let m = reference.len();
    let mut volume = 0.0f64;
    for subset in 1u32..(1u32 << n) {
        // intersection of the dominated boxes of the subset's members:
        // per-objective max of the corner coordinates
        let mut vol = 1.0f64;
        for obj in 0..m {
            let corner = (0..n)
                .filter(|&i| subset & (1 << i) != 0)
                .map(|i| front[i][obj])
                .fold(f64::NEG_INFINITY, f64::max);
            vol *= (reference[obj] - corner).max(0.0);
        }
        if subset.count_ones() % 2 == 1 {
            volume += vol;
        } else {
            volume -= vol;
        }
    }
    volume
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pts(rows: &[&[f64]]) -> Vec<Vec<f64>> {
        rows.iter().map(|r| r.to_vec()).collect()
    }

    #[test]
    fn dominance_basics() {
        assert!(dominates_min(&[1.0, 1.0], &[2.0, 1.0]));
        assert!(!dominates_min(&[1.0, 1.0], &[1.0, 1.0]));
        assert!(!dominates_min(&[1.0, 2.0], &[2.0, 1.0]));
        // length mismatch is "no", never a panic
        assert!(!dominates_min(&[1.0], &[2.0, 1.0]));
    }

    #[test]
    fn front_keeps_ties_and_trades() {
        let p = pts(&[&[1.0, 5.0], &[5.0, 1.0], &[1.0, 5.0], &[6.0, 6.0]]);
        assert_eq!(pareto_front_min(&p), vec![0, 1, 2]);
        assert!(pareto_front_min(&[]).is_empty());
    }

    #[test]
    fn ranks_peel_fronts() {
        let p = pts(&[&[0.0, 0.0], &[1.0, 1.0], &[2.0, 2.0], &[0.0, 3.0]]);
        assert_eq!(non_dominated_rank(&p), vec![0, 1, 2, 0]);
        // identical points share a rank instead of deadlocking the peel
        let q = pts(&[&[1.0], &[1.0]]);
        assert_eq!(non_dominated_rank(&q), vec![0, 0]);
    }

    #[test]
    fn crowding_rewards_boundaries_and_spread() {
        let p = pts(&[&[0.0, 4.0], &[1.0, 2.0], &[2.0, 1.5], &[4.0, 0.0]]);
        let d = crowding_distances(&p);
        assert_eq!(d[0], f64::INFINITY);
        assert_eq!(d[3], f64::INFINITY);
        assert!(d[1].is_finite() && d[2].is_finite());
        // point 1 sits in the larger gap on both axes
        assert!(d[1] > d[2], "{d:?}");
        assert_eq!(crowding_distances(&pts(&[&[1.0], &[2.0]])), vec![f64::INFINITY; 2]);
    }

    #[test]
    fn identical_points_keep_index_order() {
        // three (or more) exact ties: every objective is degenerate, so
        // crowding is 0 for all of them and nsga_order falls back to
        // the index tie-break instead of arbitrarily favouring the
        // sort's first/last elements
        let p = pts(&[&[1.0, 2.0], &[1.0, 2.0], &[1.0, 2.0], &[1.0, 2.0]]);
        assert_eq!(crowding_distances(&p), vec![0.0; 4]);
        assert_eq!(nsga_order(&p), vec![0, 1, 2, 3]);
    }

    #[test]
    fn nsga_order_is_rank_then_crowding_then_index() {
        let p = pts(&[
            &[0.0, 4.0], // front boundary
            &[2.0, 2.0], // front interior
            &[1.0, 2.5], // front interior, lonelier
            &[4.0, 0.0], // front boundary
            &[5.0, 5.0], // rank 1
        ]);
        let order = nsga_order(&p);
        assert_eq!(*order.last().unwrap(), 4);
        // boundaries (inf crowding) come before interiors, stable by index
        assert_eq!(&order[..2], &[0, 3]);
    }

    #[test]
    fn hypervolume_exact_on_small_fronts() {
        let reference = [4.0, 4.0];
        // one point: a 2x2 box
        assert_eq!(hypervolume(&pts(&[&[2.0, 2.0]]), &reference), 4.0);
        // two trading points: union of boxes, overlap counted once
        let hv = hypervolume(&pts(&[&[1.0, 3.0], &[3.0, 1.0]]), &reference);
        assert_eq!(hv, 3.0 + 3.0 - 1.0);
        // dominated points add nothing; out-of-reference points ignored
        let hv2 = hypervolume(
            &pts(&[&[1.0, 3.0], &[3.0, 1.0], &[3.5, 3.5], &[5.0, 0.0]]),
            &reference,
        );
        assert_eq!(hv2, hv);
        assert_eq!(hypervolume(&[], &reference), 0.0);
    }
}
