//! Cheap-estimator prefilter: rank candidates by hardware-only
//! objectives before spending full training probes.
//!
//! A full variant evaluation trains and searches a model end to end —
//! seconds to minutes.  A synthesis estimation is microseconds.  When a
//! strategy generates more candidates than it can afford to evaluate,
//! the prefilter orders them by what the estimator alone can see: it
//! applies each candidate's *hardware-stage* CFG overrides
//! (`reuse_factor`, `clock_period`, `FPGA_part_number`, `IOType`) to a
//! dense baseline HLS model of the flow's DNN, estimates every
//! configuration through [`ProbeService::estimate_batch`] (so repeats
//! hit the shared [`crate::dse::HwCache`] across the whole search), and
//! orders the batch by NSGA rank over (DSP, LUT, latency_ns) — the
//! same dominance kernel the real front uses, just on the cheap
//! objectives.
//!
//! It is a *heuristic*: candidates differing only in software-stage
//! dimensions (pruning tolerance, epochs) estimate identically and
//! keep their proposal order.  It never changes what a strategy is
//! allowed to evaluate, only which surplus proposals get cut first,
//! and it is deterministic for any worker count (batch results come
//! back in request order).

use std::sync::Arc;

use crate::config::FlowSpec;
use crate::dse::{HwProbeRequest, ProbeService, ProbeTiers};
use crate::error::Result;
use crate::flow::Session;
use crate::hls::{HlsModel, HlsTransform, IoType, SetReuseFactor};
use crate::json::Value;
use crate::model::state::Precision;
use crate::search::pareto::nsga_order;
use crate::search::space::{Candidate, SearchSpace};
use crate::search::CandidateRanker;
use crate::synth::FpgaDevice;

/// The baseline model + shared probe service behind one search's
/// prefilter.
pub struct HwPrefilter {
    base: HlsModel,
    service: Arc<dyn ProbeService>,
    /// The hardware-stage parameters `configure` looks up, with their
    /// instance-scope suffixes precomputed once — `configure` runs per
    /// candidate on every `rank` call, and rebuilding `".{param}"`
    /// there put an allocation in the hot candidate loop.
    part: HwParam,
    clock: HwParam,
    io: HwParam,
    reuse: HwParam,
}

/// A CFG parameter name plus its precomputed `".{param}"` suffix for
/// instance-scoped keys like `hls.clock_period`.
struct HwParam {
    name: &'static str,
    suffix: String,
}

impl HwParam {
    fn new(name: &'static str) -> HwParam {
        HwParam { name, suffix: format!(".{name}") }
    }

    /// Last CFG entry whose key is exactly the parameter or ends in
    /// its dotted suffix.
    fn get<'a>(&self, cfg: &'a [(String, Value)]) -> Option<&'a Value> {
        cfg.iter()
            .rev()
            .find(|(k, _)| k == self.name || k.ends_with(&self.suffix))
            .map(|(_, v)| v)
    }
}

/// One-off lookup form of [`HwParam::get`] (build-time defaults; the
/// per-candidate path uses the precomputed suffixes instead).
fn hw_param<'a>(cfg: &'a [(String, Value)], param: &str) -> Option<&'a Value> {
    let dotted = |k: &str| {
        k.len() > param.len() + 1
            && k.ends_with(param)
            && k.as_bytes()[k.len() - param.len() - 1] == b'.'
    };
    cfg.iter().rev().find(|(k, _)| k == param || dotted(k)).map(|(_, v)| v)
}

impl HwPrefilter {
    /// Build the baseline: the spec's model (scale 1.0, dense masks,
    /// default datapath precision) on the spec's hardware defaults.
    /// Fails cleanly when the session's manifest has no such variant —
    /// strategies then fall back to their non-prefiltered ordering.
    pub fn build(
        session: &Session,
        spec: &FlowSpec,
        extra_cfg: &[(String, Value)],
        shared: &ProbeTiers,
        jobs: usize,
    ) -> Result<HwPrefilter> {
        let mut defaults: Vec<(String, Value)> = spec.cfg_entries.clone();
        defaults.extend(extra_cfg.iter().cloned());
        let model = hw_param(&defaults, "model")
            .and_then(Value::as_str)
            .unwrap_or("jet_dnn");
        let variant = session.manifest.variant(model, 1.0)?.clone();
        let part = hw_param(&defaults, "FPGA_part_number")
            .and_then(Value::as_str)
            .unwrap_or("vu9p")
            .to_string();
        let clock_ns = hw_param(&defaults, "clock_period")
            .and_then(Value::as_f64)
            .filter(|&c| c > 0.0)
            .unwrap_or(5.0);
        // dense baseline: empty nnz list = every mask fully populated
        let base =
            HlsModel::from_nnz(&variant, &[], Precision::new(18, 8), &part, clock_ns)?;
        // validate the default target once so a bad part fails at build
        // time, not on the first rank() call
        FpgaDevice::target_of(&base)?;
        Ok(HwPrefilter {
            base,
            service: shared.service(jobs),
            part: HwParam::new("FPGA_part_number"),
            clock: HwParam::new("clock_period"),
            io: HwParam::new("IOType"),
            reuse: HwParam::new("reuse_factor"),
        })
    }

    /// Apply a candidate's hardware-stage overrides to the baseline.
    fn configure(&self, cfg: &[(String, Value)]) -> Result<HlsModel> {
        let mut m = self.base.clone();
        if let Some(part) = self.part.get(cfg).and_then(Value::as_str) {
            m.fpga_part = part.to_string();
        }
        if let Some(clock) = self.clock.get(cfg).and_then(Value::as_f64) {
            if clock > 0.0 {
                m.clock_period_ns = clock;
            }
        }
        if let Some(io) = self.io.get(cfg).and_then(Value::as_str) {
            m.io_type = if io == "io_stream" { IoType::Stream } else { IoType::Parallel };
        }
        if let Some(rf) = self.reuse.get(cfg).and_then(Value::as_usize) {
            if rf > 1 {
                SetReuseFactor(rf).apply(&mut m)?;
            }
        }
        Ok(m)
    }

    /// Order candidate indices best-first by NSGA rank / crowding over
    /// estimated (DSP, LUT, latency_ns), stable in the input order for
    /// hardware-identical candidates.
    pub fn rank(&self, space: &SearchSpace, candidates: &[Candidate]) -> Result<Vec<usize>> {
        let models: Vec<HlsModel> = candidates
            .iter()
            .map(|c| self.configure(&space.candidate_cfg(c)))
            .collect::<Result<_>>()?;
        // estimate_batch takes one (device, clock) per batch, so group
        // candidates by target; results land back in their input slots
        let mut objectives: Vec<Vec<f64>> = vec![Vec::new(); models.len()];
        let mut groups: Vec<(String, u64, Vec<usize>)> = Vec::new();
        for (i, m) in models.iter().enumerate() {
            let (device, clock_mhz) = FpgaDevice::target_of(m)?;
            let tag = (device.name.to_string(), clock_mhz.to_bits());
            match groups.iter_mut().find(|(n, c, _)| *n == tag.0 && *c == tag.1) {
                Some((_, _, idxs)) => idxs.push(i),
                None => groups.push((tag.0, tag.1, vec![i])),
            }
        }
        for (name, clock_bits, idxs) in groups {
            let device = FpgaDevice::by_name(&name).expect("grouped by resolved device");
            let clock_mhz = f64::from_bits(clock_bits);
            let requests: Vec<HwProbeRequest> = idxs
                .iter()
                .map(|&i| HwProbeRequest::new(i, models[i].clone()))
                .collect();
            for r in self.service.estimate_batch(device, clock_mhz, &requests)? {
                objectives[r.id] =
                    vec![r.eval.dsp as f64, r.eval.lut as f64, r.eval.latency_ns];
            }
        }
        Ok(nsga_order(&objectives))
    }
}

impl CandidateRanker for HwPrefilter {
    fn rank(&self, space: &SearchSpace, candidates: &[Candidate]) -> Result<Vec<usize>> {
        HwPrefilter::rank(self, space, candidates)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precomputed_hw_param_matches_free_lookup() {
        let cfg = vec![
            ("clock_period".to_string(), Value::Number(5.0)),
            ("hls.clock_period".to_string(), Value::Number(10.0)),
            ("xclock_period".to_string(), Value::Number(1.0)),
        ];
        let p = HwParam::new("clock_period");
        assert_eq!(p.get(&cfg).and_then(Value::as_f64), Some(10.0));
        assert_eq!(
            p.get(&cfg).and_then(Value::as_f64),
            hw_param(&cfg, "clock_period").and_then(Value::as_f64)
        );
        assert!(HwParam::new("reuse_factor").get(&cfg).is_none());
    }

    #[test]
    fn hw_param_matches_global_and_instance_scoped_keys() {
        let cfg = vec![
            ("clock_period".to_string(), Value::Number(5.0)),
            ("hls.clock_period".to_string(), Value::Number(10.0)),
            ("prune.tolerate_acc_loss".to_string(), Value::Number(0.02)),
        ];
        // last match wins (instance-scoped override after the global)
        assert_eq!(hw_param(&cfg, "clock_period").and_then(Value::as_f64), Some(10.0));
        assert!(hw_param(&cfg, "reuse_factor").is_none());
        // a suffix must be a whole dotted segment
        let odd = vec![("xclock_period".to_string(), Value::Number(1.0))];
        assert!(hw_param(&odd, "clock_period").is_none());
    }
}
