//! Online learned surrogate: replace most training probes with a
//! ridge-regression predictor fitted as the search runs.
//!
//! A full variant evaluation trains and searches a model end to end;
//! the hardware prefilter shortcuts *hardware-only* dimensions but is
//! blind to training-affecting ones (pruning tolerance, quantization
//! settings, task order).  The surrogate closes that gap: it encodes
//! the **complete** candidate vector — numeric dimensions standardized,
//! categorical/grid values one-hot, task orders as per-task permutation
//! position features — and fits one linear ridge model per front
//! objective (accuracy, DSP, LUT, latency_ns) **online** from the
//! truth evaluations the search has already paid for (cf.
//! "Software-defined Design Space Exploration" and AutoDNNchip, whose
//! predictors reach near-optimal designs at a fraction of the
//! evaluations).
//!
//! The fit is pure Rust and exactly deterministic: a fixed feature
//! order, observations in evaluation order, normal equations solved by
//! a hand-rolled Cholesky factorization — no RNG, no iteration-order
//! hashing, no crates.io dependencies.  For a fixed (spec, strategy,
//! seed, budget) every prediction is bit-identical for any `--jobs`,
//! which is what lets the driver make *policy* decisions (evaluate vs
//! defer) from predictions without breaking the search determinism
//! contract.
//!
//! **Evaluation policy** (driven by [`crate::search::driver`]):
//!
//! 1. **Warmup** — the first `warmup` evaluations are real and chosen
//!    by the driver as a space-filling strided sample of the grid, so
//!    every dimension shows variance before the model is trusted.
//! 2. **Band** — once fitted, each proposal batch is ranked by
//!    predicted NSGA order; a candidate is **deferred** (no flow run,
//!    no training probes) only when its prediction — given an optimism
//!    margin of `trust radius × per-objective spread` — is still
//!    dominated by an already-evaluated point.  Everything else (the
//!    predicted-front band) spends real probes.
//! 3. **Re-validation** — every `every` rounds the best-predicted
//!    deferred candidate is truth-evaluated; at search end, deferred
//!    candidates whose re-predicted objectives are not dominated by
//!    the truth set are evaluated until none remain.  Every truth
//!    evaluation of a predicted point feeds the observed error back:
//!    error above `threshold` doubles the trust radius (the band
//!    widens toward "evaluate everything", so a hostile space degrades
//!    gracefully to exhaustive behavior), low error decays it back.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::dse::ProbeStats;
use crate::error::{Error, Result};
use crate::json::Value;
use crate::search::pareto::{dominates_min, nsga_order};
use crate::search::space::{Candidate, SearchSpace};
use crate::search::CandidateRanker;

/// Default observations before predictions may gate evaluations
/// (raised to `n_features + 1` when the encoding is wider).
pub const DEFAULT_WARMUP: usize = 4;
/// Default initial trust radius (optimism margin as a fraction of the
/// per-objective truth spread).
pub const DEFAULT_MARGIN: f64 = 0.1;
/// Default re-validation cadence (rounds between truth-evaluating the
/// top deferred candidate).
pub const DEFAULT_EVERY: usize = 2;
/// Default relative prediction error above which the trust radius
/// doubles.
pub const DEFAULT_THRESHOLD: f64 = 0.2;
/// Default ridge regularization strength (λ per observation).
pub const DEFAULT_RIDGE: f64 = 1e-6;
/// Trust radius cap: at this many spreads of optimism nothing is ever
/// deferred, i.e. the policy has degraded to exhaustive behavior.
const RADIUS_CAP: f64 = 8.0;
/// Trust radius decay factor applied on an accurate prediction.
const RADIUS_DECAY: f64 = 0.9;

/// The parsed `search.surrogate` section (or its CLI override).
#[derive(Debug, Clone, PartialEq)]
pub struct SurrogateSpec {
    /// Real evaluations before predictions gate anything
    /// (`None` = `max(DEFAULT_WARMUP, n_features + 1)`).
    pub warmup: Option<usize>,
    /// Initial trust radius (fraction of per-objective spread).
    pub margin: f64,
    /// Re-validate the top deferred candidate every this many rounds.
    pub every: usize,
    /// Relative error above which the trust radius doubles.
    pub threshold: f64,
    /// Ridge regularization λ (scaled by observation count).
    pub ridge: f64,
}

impl Default for SurrogateSpec {
    fn default() -> Self {
        SurrogateSpec {
            warmup: None,
            margin: DEFAULT_MARGIN,
            every: DEFAULT_EVERY,
            threshold: DEFAULT_THRESHOLD,
            ridge: DEFAULT_RIDGE,
        }
    }
}

impl SurrogateSpec {
    /// Parse `"surrogate": true` or a full
    /// `{"warmup": N, "margin": x, "every": K, "threshold": x,
    ///   "ridge": x}` object.  Unknown keys are rejected.
    pub fn parse(v: &Value) -> Result<SurrogateSpec> {
        match v {
            Value::Bool(true) => Ok(SurrogateSpec::default()),
            Value::Bool(false) => Err(Error::Config(
                "search surrogate: use `true` or an options object to enable it \
                 (omit the key to disable)"
                    .into(),
            )),
            Value::Object(map) => {
                let mut spec = SurrogateSpec::default();
                for (key, val) in map {
                    match key.as_str() {
                        "warmup" => {
                            let w = val.as_usize().filter(|&w| w >= 1).ok_or_else(|| {
                                Error::Config(
                                    "search surrogate warmup must be a positive integer".into(),
                                )
                            })?;
                            spec.warmup = Some(w);
                        }
                        "margin" => {
                            spec.margin = val
                                .as_f64()
                                .filter(|m| m.is_finite() && *m >= 0.0)
                                .ok_or_else(|| {
                                    Error::Config(
                                        "search surrogate margin must be a non-negative number"
                                            .into(),
                                    )
                                })?;
                        }
                        "every" => {
                            spec.every = val.as_usize().filter(|&e| e >= 1).ok_or_else(|| {
                                Error::Config(
                                    "search surrogate every must be a positive integer".into(),
                                )
                            })?;
                        }
                        "threshold" => {
                            spec.threshold = val
                                .as_f64()
                                .filter(|t| t.is_finite() && *t > 0.0)
                                .ok_or_else(|| {
                                    Error::Config(
                                        "search surrogate threshold must be a positive number"
                                            .into(),
                                    )
                                })?;
                        }
                        "ridge" => {
                            spec.ridge = val
                                .as_f64()
                                .filter(|r| r.is_finite() && *r > 0.0)
                                .ok_or_else(|| {
                                    Error::Config(
                                        "search surrogate ridge must be a positive number".into(),
                                    )
                                })?;
                        }
                        other => {
                            return Err(Error::Config(format!(
                                "unknown search surrogate key {other:?} (valid: warmup, \
                                 margin, every, threshold, ridge)"
                            )));
                        }
                    }
                }
                Ok(spec)
            }
            _ => Err(Error::Config(
                "search surrogate must be `true` or an options object".into(),
            )),
        }
    }
}

/// What one surrogate-guided run did, surfaced in the explore summary
/// and `front_csv` columns.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SurrogateReport {
    /// Model refits over the run.
    pub fits: usize,
    /// Objective-vector predictions served.
    pub predictions: usize,
    /// Proposals answered by prediction instead of a flow evaluation.
    pub deferred: usize,
    /// Deferred candidates later truth-evaluated (periodic + final
    /// re-validation).
    pub validated: usize,
    /// Mean absolute prediction error per objective
    /// (minimization order: -accuracy, dsp, lut, latency_ns), over
    /// every truth-evaluated prediction.  Empty until one lands.
    pub mean_abs_error: Vec<f64>,
}

impl SurrogateReport {
    /// Net flow evaluations avoided: deferrals that never needed a
    /// truth evaluation after all.
    pub fn probes_saved(&self) -> usize {
        self.deferred.saturating_sub(self.validated)
    }
}

/// How one discrete grid dimension is encoded.
#[derive(Debug, Clone)]
enum GridEnc {
    /// All candidate values numeric: one standardized column holding
    /// the value itself.
    Numeric(Vec<f64>),
    /// Mixed/categorical values: one 0/1 column per value index.
    OneHot(usize),
}

/// Deterministic candidate → feature-vector encoding with a fixed
/// column order: task-order permutation features, then grid dimensions
/// in declaration order, then range dimensions.
#[derive(Debug, Clone)]
struct Encoder {
    /// Per order option, one row of per-task normalized positions
    /// (empty when the space has a single order — no variance to
    /// learn).
    order_feats: Vec<Vec<f64>>,
    grid: Vec<GridEnc>,
    n_ranges: usize,
    n_features: usize,
}

impl Encoder {
    fn of(space: &SearchSpace) -> Encoder {
        // task-order permutation features: position of each task
        // (canonical sorted name order) within the variant's chain,
        // normalized to [0, 1]
        let order_feats: Vec<Vec<f64>> = if space.orders.len() > 1 {
            let mut canon: Vec<String> = space
                .orders
                .iter()
                .flatten()
                .next()
                .cloned()
                .unwrap_or_default();
            canon.sort_unstable();
            let denom = (canon.len().saturating_sub(1)).max(1) as f64;
            space
                .orders
                .iter()
                .map(|o| match o {
                    Some(order) => canon
                        .iter()
                        .map(|t| {
                            order.iter().position(|x| x == t).unwrap_or(0) as f64 / denom
                        })
                        .collect(),
                    None => canon
                        .iter()
                        .enumerate()
                        .map(|(i, _)| i as f64 / denom)
                        .collect(),
                })
                .collect()
        } else {
            vec![Vec::new(); space.orders.len()]
        };
        let grid: Vec<GridEnc> = space
            .grid
            .iter()
            .map(|(_, vals)| {
                let nums: Option<Vec<f64>> = vals.iter().map(Value::as_f64).collect();
                match nums {
                    Some(ns) => GridEnc::Numeric(ns),
                    None => GridEnc::OneHot(vals.len()),
                }
            })
            .collect();
        let n_features = order_feats.first().map_or(0, Vec::len)
            + grid
                .iter()
                .map(|g| match g {
                    GridEnc::Numeric(_) => 1,
                    GridEnc::OneHot(k) => *k,
                })
                .sum::<usize>()
            + space.ranges.len();
        Encoder { order_feats, grid, n_ranges: space.ranges.len(), n_features }
    }

    fn encode(&self, c: &Candidate) -> Vec<f64> {
        let mut x = Vec::with_capacity(self.n_features);
        x.extend_from_slice(&self.order_feats[c.order.min(self.order_feats.len() - 1)]);
        for (enc, &gi) in self.grid.iter().zip(&c.grid) {
            match enc {
                GridEnc::Numeric(vals) => x.push(vals[gi.min(vals.len() - 1)]),
                GridEnc::OneHot(k) => {
                    for j in 0..*k {
                        x.push(if j == gi { 1.0 } else { 0.0 });
                    }
                }
            }
        }
        x.extend(c.range.iter().take(self.n_ranges).copied());
        x
    }
}

/// One fitted multi-output ridge model: standardized features,
/// centered targets, per-objective weight rows.
#[derive(Debug, Clone)]
struct Fit {
    mu: Vec<f64>,
    /// Population std per feature; 0 marks a dropped (constant)
    /// column.
    sigma: Vec<f64>,
    ybar: Vec<f64>,
    /// `w[objective][feature]` over standardized columns.
    w: Vec<Vec<f64>>,
}

impl Fit {
    fn predict(&self, x: &[f64]) -> Vec<f64> {
        self.ybar
            .iter()
            .zip(&self.w)
            .map(|(&yb, row)| {
                let mut y = yb;
                for (j, &wj) in row.iter().enumerate() {
                    if self.sigma[j] > 0.0 {
                        y += wj * (x[j] - self.mu[j]) / self.sigma[j];
                    }
                }
                y
            })
            .collect()
    }
}

/// In-place Cholesky factorization of a symmetric positive-definite
/// matrix (row-major, `n × n`), leaving the lower triangle `L` with
/// `L·Lᵀ = A`.  Fails on a non-positive pivot (caller bumps the ridge
/// and retries).
fn cholesky(a: &mut [f64], n: usize) -> Result<()> {
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= a[i * n + k] * a[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return Err(Error::Config(format!(
                        "surrogate: normal equations not positive definite (pivot {s})"
                    )));
                }
                a[i * n + i] = s.sqrt();
            } else {
                a[i * n + j] = s / a[j * n + j];
            }
        }
    }
    Ok(())
}

/// Solve `L·Lᵀ·x = b` given the Cholesky factor `L`.
fn chol_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * y[k];
        }
        y[i] = s / l[i * n + i];
    }
    let mut x = vec![0.0f64; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

/// Multi-output ridge regression by normal equations + Cholesky:
/// standardize columns (constant columns dropped), center targets,
/// solve `(ZᵀZ + λ·n·I)·w = Zᵀ(y − ȳ)` per objective.  Exposed for the
/// linear-recovery tests; everything is deterministic in the input
/// order.
pub(crate) fn ridge_fit_raw(
    xs: &[Vec<f64>],
    ys: &[Vec<f64>],
    lambda: f64,
) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, Vec<Vec<f64>>)> {
    let n = xs.len();
    if n < 2 {
        return Err(Error::Config("surrogate: need at least 2 observations".into()));
    }
    let d = xs[0].len();
    let m = ys[0].len();
    let mut mu = vec![0.0f64; d];
    for x in xs {
        for (j, &v) in x.iter().enumerate() {
            mu[j] += v;
        }
    }
    for v in &mut mu {
        *v /= n as f64;
    }
    let mut sigma = vec![0.0f64; d];
    for x in xs {
        for (j, &v) in x.iter().enumerate() {
            sigma[j] += (v - mu[j]) * (v - mu[j]);
        }
    }
    for v in &mut sigma {
        *v = (*v / n as f64).sqrt();
        if !v.is_finite() || *v < 1e-12 {
            *v = 0.0; // constant column: dropped
        }
    }
    let z = |x: &[f64], j: usize| -> f64 {
        if sigma[j] > 0.0 {
            (x[j] - mu[j]) / sigma[j]
        } else {
            0.0
        }
    };
    let mut ybar = vec![0.0f64; m];
    for y in ys {
        for (o, &v) in y.iter().enumerate() {
            ybar[o] += v;
        }
    }
    for v in &mut ybar {
        *v /= n as f64;
    }

    // Zᵀ·Z and Zᵀ·(y − ȳ), dense (d is small: one column per encoded
    // dimension)
    let mut ztz = vec![0.0f64; d * d];
    let mut zty = vec![vec![0.0f64; d]; m];
    for (x, y) in xs.iter().zip(ys) {
        for j in 0..d {
            let zj = z(x, j);
            if zj == 0.0 {
                continue;
            }
            for k in 0..=j {
                ztz[j * d + k] += zj * z(x, k);
            }
            for o in 0..m {
                zty[o][j] += zj * (y[o] - ybar[o]);
            }
        }
    }
    for j in 0..d {
        for k in j + 1..d {
            ztz[j * d + k] = ztz[k * d + j];
        }
    }

    let mut lambda = lambda.max(1e-12);
    for _ in 0..8 {
        let mut a = ztz.clone();
        for j in 0..d {
            a[j * d + j] += lambda * n as f64;
        }
        if cholesky(&mut a, d).is_ok() {
            let w: Vec<Vec<f64>> = zty.iter().map(|b| chol_solve(&a, d, b)).collect();
            return Ok((mu, sigma, ybar, w));
        }
        lambda *= 10.0; // numerically degenerate: regularize harder
    }
    Err(Error::Config("surrogate: ridge system stayed indefinite".into()))
}

/// The online surrogate one search run owns: encoder, observation
/// store, current fit, trust radius and accounting.
pub struct Surrogate {
    spec: SurrogateSpec,
    enc: Encoder,
    warmup: usize,
    warmed: bool,
    obs_x: Vec<Vec<f64>>,
    obs_y: Vec<Vec<f64>>,
    fit: Option<Fit>,
    dirty: bool,
    /// Optimism margin in units of per-objective truth spread.
    radius: f64,
    fits: usize,
    predictions: AtomicUsize,
    deferred: usize,
    validated: usize,
    err_sum: Vec<f64>,
    err_n: usize,
    stats: Arc<ProbeStats>,
}

impl Surrogate {
    pub fn new(space: &SearchSpace, spec: &SurrogateSpec, stats: Arc<ProbeStats>) -> Surrogate {
        let enc = Encoder::of(space);
        let warmup = spec.warmup.unwrap_or_else(|| DEFAULT_WARMUP.max(enc.n_features + 1));
        Surrogate {
            spec: spec.clone(),
            warmup,
            warmed: false,
            radius: spec.margin,
            enc,
            obs_x: Vec::new(),
            obs_y: Vec::new(),
            fit: None,
            dirty: false,
            fits: 0,
            predictions: AtomicUsize::new(0),
            deferred: 0,
            validated: 0,
            err_sum: Vec::new(),
            err_n: 0,
            stats,
        }
    }

    /// Warmup evaluations the driver owes before predictions gate
    /// anything.
    pub fn warmup(&self) -> usize {
        self.warmup
    }

    /// Re-validation cadence in rounds.
    pub fn every(&self) -> usize {
        self.spec.every
    }

    /// The driver finished its warmup phase (possibly short of
    /// `warmup` points on tiny grids/budgets).
    pub fn finish_warmup(&mut self) {
        self.warmed = true;
    }

    /// Predictions may gate evaluations: warmup done and a model
    /// fitted.
    pub fn ready(&self) -> bool {
        self.warmed && self.fit.is_some()
    }

    /// Record one truth evaluation (objectives in the shared
    /// minimization convention, evaluation order = observation order).
    pub fn observe_truth(&mut self, c: &Candidate, objectives: &[f64]) {
        self.obs_x.push(self.enc.encode(c));
        self.obs_y.push(objectives.to_vec());
        self.dirty = true;
    }

    /// Refit if new observations arrived since the last fit.  Never
    /// fails the search: a degenerate system just leaves the previous
    /// fit (or none) in place.
    pub fn fit_if_dirty(&mut self) {
        if !self.dirty || self.obs_x.len() < 2 {
            return;
        }
        self.dirty = false;
        let mut span = crate::obs::trace::span("search", "surrogate.fit");
        span.arg("observations", self.obs_x.len());
        if let Ok((mu, sigma, ybar, w)) =
            ridge_fit_raw(&self.obs_x, &self.obs_y, self.spec.ridge)
        {
            self.fit = Some(Fit { mu, sigma, ybar, w });
            self.fits += 1;
            self.stats.note_surrogate_fit();
        }
    }

    /// Predict the objective vector for a candidate.  Only meaningful
    /// when [`Self::ready`]; without a fit it returns the observation
    /// mean (never panics).
    pub fn predict(&self, c: &Candidate) -> Vec<f64> {
        // counted predictions are part of the replayable trace, so the
        // span structure is deterministic too; predict_quiet stays
        // unspanned (speculative volume is wall-clock-dependent)
        let _span = crate::obs::trace::span("search", "surrogate.predict");
        self.predictions.fetch_add(1, Ordering::Relaxed);
        self.stats.note_surrogate_prediction();
        let x = self.enc.encode(c);
        match &self.fit {
            Some(f) => f.predict(&x),
            None => {
                let m = self.obs_y.first().map_or(0, Vec::len);
                let n = self.obs_y.len().max(1) as f64;
                (0..m)
                    .map(|o| self.obs_y.iter().map(|y| y[o]).sum::<f64>() / n)
                    .collect()
            }
        }
    }

    /// [`Self::predict`] without touching the prediction counters —
    /// for *speculative* consumers (the pipelined scheduler's
    /// guess-gating) whose queries must not perturb the replayable
    /// `sur_predictions` accounting.
    pub fn predict_quiet(&self, c: &Candidate) -> Vec<f64> {
        let x = self.enc.encode(c);
        match &self.fit {
            Some(f) => f.predict(&x),
            None => {
                let m = self.obs_y.first().map_or(0, Vec::len);
                let n = self.obs_y.len().max(1) as f64;
                (0..m)
                    .map(|o| self.obs_y.iter().map(|y| y[o]).sum::<f64>() / n)
                    .collect()
            }
        }
    }

    /// Would the deferral policy sideline this candidate right now?
    /// Uncounted ([`Self::predict_quiet`]) — a speculation-only probe
    /// of the policy, never part of the observed trace.
    pub fn would_defer(&self, c: &Candidate, truth: &[Vec<f64>]) -> bool {
        self.ready() && self.defer(&self.predict_quiet(c), truth)
    }

    /// Per-objective spread (max − min) over the truth observations.
    fn spreads(truth: &[Vec<f64>]) -> Vec<f64> {
        let m = truth.first().map_or(0, Vec::len);
        (0..m)
            .map(|o| {
                let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
                for t in truth {
                    lo = lo.min(t[o]);
                    hi = hi.max(t[o]);
                }
                (hi - lo).max(0.0)
            })
            .collect()
    }

    /// Should a freshly-predicted candidate be deferred?  Only when
    /// its prediction, granted an optimism margin of
    /// `radius × spread` per objective, is still dominated by some
    /// already-evaluated point.  Call [`Self::note_deferred`] when the
    /// driver acts on a `true`.
    pub fn defer(&self, predicted: &[f64], truth: &[Vec<f64>]) -> bool {
        if truth.is_empty() {
            return false;
        }
        let spreads = Self::spreads(truth);
        let optimistic: Vec<f64> = predicted
            .iter()
            .zip(&spreads)
            .map(|(&p, &s)| p - self.radius * s)
            .collect();
        truth.iter().any(|t| dominates_min(t, &optimistic))
    }

    pub fn note_deferred(&mut self) {
        self.deferred += 1;
    }

    pub fn note_validated(&mut self) {
        self.validated += 1;
    }

    /// Feed back the error of a prediction whose truth arrived (band
    /// evaluations and re-validations alike): accumulate the
    /// per-objective absolute error and adapt the trust radius —
    /// relative error above the threshold doubles it (wider band, less
    /// deferral), accurate predictions decay it back toward the
    /// configured margin.
    pub fn record_error(&mut self, predicted: &[f64], truth_point: &[f64], truth: &[Vec<f64>]) {
        if self.err_sum.len() < predicted.len() {
            self.err_sum.resize(predicted.len(), 0.0);
        }
        let spreads = Self::spreads(truth);
        let mut rel = 0.0f64;
        for (o, (&p, &t)) in predicted.iter().zip(truth_point).enumerate() {
            let err = (p - t).abs();
            self.err_sum[o] += err;
            let scale = spreads[o].max(1e-6 * t.abs().max(1.0));
            rel = rel.max(err / scale);
        }
        self.err_n += 1;
        if rel > self.spec.threshold {
            self.radius = (self.radius * 2.0).max(self.spec.margin.max(1e-3)).min(RADIUS_CAP);
        } else {
            self.radius = (self.radius * RADIUS_DECAY).max(self.spec.margin);
        }
    }

    /// Current trust radius (optimism margin in spread units).
    pub fn trust_radius(&self) -> f64 {
        self.radius
    }

    pub fn report(&self) -> SurrogateReport {
        SurrogateReport {
            fits: self.fits,
            predictions: self.predictions.load(Ordering::Relaxed),
            deferred: self.deferred,
            validated: self.validated,
            mean_abs_error: if self.err_n == 0 {
                Vec::new()
            } else {
                self.err_sum.iter().map(|s| s / self.err_n as f64).collect()
            },
        }
    }
}

impl CandidateRanker for Surrogate {
    /// Best-first by NSGA rank/crowding over *predicted* objectives —
    /// the full-candidate-vector counterpart of the hardware
    /// prefilter's estimator ranking, stable in input order for
    /// prediction ties.
    fn rank(&self, _space: &SearchSpace, candidates: &[Candidate]) -> Result<Vec<usize>> {
        let objectives: Vec<Vec<f64>> = candidates.iter().map(|c| self.predict(c)).collect();
        Ok(nsga_order(&objectives))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::ProbeStats;
    use crate::search::space::RangeDim;

    fn numeric_space() -> SearchSpace {
        SearchSpace {
            orders: vec![None],
            grid: vec![
                ("a".to_string(), vec![0.0, 1.0, 2.0, 3.0].into_iter().map(Value::Number).collect()),
                ("b".to_string(), vec![0.0, 5.0, 10.0].into_iter().map(Value::Number).collect()),
            ],
            ranges: Vec::new(),
        }
    }

    fn cand(a: usize, b: usize) -> Candidate {
        Candidate { order: 0, grid: vec![a, b], range: Vec::new() }
    }

    #[test]
    fn encoder_fixed_order_numeric_onehot_and_permutations() {
        let space = SearchSpace {
            orders: vec![
                Some(vec!["p".into(), "q".into()]),
                Some(vec!["q".into(), "p".into()]),
            ],
            grid: vec![
                ("k".to_string(), vec![Value::Number(2.0), Value::Number(8.0)]),
                (
                    "io".to_string(),
                    vec![Value::String("par".into()), Value::String("str".into())],
                ),
            ],
            ranges: vec![("r".to_string(), RangeDim { lo: 0.0, hi: 1.0, integer: false })],
        };
        let enc = Encoder::of(&space);
        // 2 permutation features + 1 numeric + 2 one-hot + 1 range
        assert_eq!(enc.n_features, 6);
        let c = Candidate { order: 1, grid: vec![0, 1], range: vec![0.25] };
        // order "q-p": p at position 1, q at position 0 (canonical sorted)
        assert_eq!(enc.encode(&c), vec![1.0, 0.0, 2.0, 0.0, 1.0, 0.25]);
        let c0 = Candidate { order: 0, grid: vec![1, 0], range: vec![0.75] };
        assert_eq!(enc.encode(&c0), vec![0.0, 1.0, 8.0, 1.0, 0.0, 0.75]);
    }

    #[test]
    fn ridge_recovers_linear_objectives_exactly() {
        let space = numeric_space();
        let spec = SurrogateSpec { ridge: 1e-9, warmup: Some(1), ..Default::default() };
        let mut sur = Surrogate::new(&space, &spec, Arc::new(ProbeStats::default()));
        // y0 = 2 + 3a − b, y1 = 7 − a over a training subset
        for (a, b) in [(0usize, 0usize), (1, 1), (2, 2), (3, 0), (0, 2), (2, 1)] {
            let av = a as f64;
            let bv = [0.0, 5.0, 10.0][b];
            sur.observe_truth(&cand(a, b), &[2.0 + 3.0 * av - bv, 7.0 - av]);
        }
        sur.finish_warmup();
        sur.fit_if_dirty();
        assert!(sur.ready());
        // held-out grid points recovered to ridge precision
        for (a, b) in [(1usize, 2usize), (3, 1), (1, 0), (3, 2)] {
            let av = a as f64;
            let bv = [0.0, 5.0, 10.0][b];
            let p = sur.predict(&cand(a, b));
            assert!((p[0] - (2.0 + 3.0 * av - bv)).abs() < 1e-5, "y0 {p:?}");
            assert!((p[1] - (7.0 - av)).abs() < 1e-5, "y1 {p:?}");
        }
        let rep = sur.report();
        assert_eq!(rep.fits, 1);
        assert_eq!(rep.predictions, 4);
    }

    #[test]
    fn fit_is_deterministic_in_observation_order() {
        let space = numeric_space();
        let spec = SurrogateSpec::default();
        let mk = || {
            let mut s = Surrogate::new(&space, &spec, Arc::new(ProbeStats::default()));
            for (a, b) in [(0usize, 0usize), (1, 2), (2, 1), (3, 0)] {
                s.observe_truth(&cand(a, b), &[a as f64 * 1.5 - b as f64, b as f64]);
            }
            s.finish_warmup();
            s.fit_if_dirty();
            s
        };
        let (s1, s2) = (mk(), mk());
        for (a, b) in [(0usize, 1usize), (2, 2), (3, 1)] {
            let (p, q) = (s1.predict(&cand(a, b)), s2.predict(&cand(a, b)));
            for (x, y) in p.iter().zip(&q) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn defer_needs_margin_dominance_and_radius_widens_on_error() {
        let space = numeric_space();
        let spec = SurrogateSpec { margin: 0.1, threshold: 0.2, ..Default::default() };
        let mut sur = Surrogate::new(&space, &spec, Arc::new(ProbeStats::default()));
        let truth = vec![vec![1.0, 1.0], vec![2.0, 0.5]];
        // clearly dominated prediction (margin 0.1 × spread 1.0/0.5)
        assert!(sur.defer(&[3.0, 3.0], &truth));
        // a tie with the best point is never deferred
        assert!(!sur.defer(&[1.0, 1.0], &truth));
        // better on one objective: evaluate
        assert!(!sur.defer(&[0.5, 4.0], &truth));

        // large error doubles the radius; an 8-spread optimism margin
        // means nothing is deferred any more (exhaustive fallback)
        let r0 = sur.trust_radius();
        for _ in 0..12 {
            sur.record_error(&[10.0, 10.0], &[1.0, 1.0], &truth);
        }
        assert!(sur.trust_radius() > r0);
        assert!((sur.trust_radius() - 8.0).abs() < 1e-12, "{}", sur.trust_radius());
        assert!(!sur.defer(&[3.0, 3.0], &truth));
        // accurate predictions decay it back toward the margin
        for _ in 0..200 {
            sur.record_error(&[1.0, 1.0], &[1.0, 1.0], &truth);
        }
        assert!((sur.trust_radius() - 0.1).abs() < 1e-9);
        let rep = sur.report();
        assert_eq!(rep.mean_abs_error.len(), 2);
        assert!(rep.mean_abs_error[0] > 0.0);
    }

    #[test]
    fn surrogate_spec_parses_bool_and_object_and_rejects_unknown() {
        let t = SurrogateSpec::parse(&crate::json::parse("true").unwrap()).unwrap();
        assert_eq!(t, SurrogateSpec::default());
        let o = SurrogateSpec::parse(
            &crate::json::parse(
                r#"{"warmup": 6, "margin": 0.2, "every": 3, "threshold": 0.5, "ridge": 0.001}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(o.warmup, Some(6));
        assert_eq!(o.every, 3);
        let bad = |s: &str| SurrogateSpec::parse(&crate::json::parse(s).unwrap()).unwrap_err();
        assert!(bad("false").to_string().contains("enable"));
        assert!(bad(r#"{"wormup": 3}"#).to_string().contains("wormup"));
        assert!(bad(r#"{"warmup": 0}"#).to_string().contains("positive"));
        assert!(bad(r#"{"ridge": 0}"#).to_string().contains("positive"));
    }
}
