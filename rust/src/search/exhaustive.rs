//! `Exhaustive`: the full discrete grid, in declaration order.
//!
//! Wraps the legacy explorer behavior as a [`SearchStrategy`]: with the
//! default budget (= grid size) it proposes every (order × cfg-grid)
//! point exactly once, in the same order [`crate::flow::explore::
//! expand_variants`] enumerates, so fronts, labels and CSVs match the
//! pre-search explorer bit-for-bit.  A smaller budget truncates the
//! sweep (a prefix scan, not a sample — use `random`/`evolve` when the
//! budget can't cover the grid).
//!
//! Numeric `range` dimensions have no finite enumeration; constructing
//! `Exhaustive` over a space that declares them is a config error
//! (enforced by [`crate::search::make_strategy`]).

use crate::error::Result;
use crate::search::driver::{Observation, SearchCtx, SearchStrategy};
use crate::search::space::Candidate;
use crate::util::prng::Prng;

#[derive(Debug, Default)]
pub struct Exhaustive {
    cursor: usize,
}

impl Exhaustive {
    pub fn new() -> Self {
        Self::default()
    }
}

impl SearchStrategy for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn propose(&mut self, ctx: &SearchCtx<'_>, limit: usize) -> Result<Vec<Candidate>> {
        let n = ctx.space.grid_size();
        let take = limit.min(n.saturating_sub(self.cursor));
        // the space has no range dims (make_strategy rejected them), so
        // decoding consumes no randomness; any seed works
        let mut prng = Prng::new(0);
        let batch: Vec<Candidate> = (self.cursor..self.cursor + take)
            .map(|i| ctx.space.nth_grid_point(i, &mut prng))
            .collect();
        self.cursor += take;
        Ok(batch)
    }

    fn observe(&mut self, _ctx: &SearchCtx<'_>, _batch: &[Observation]) {}
}
