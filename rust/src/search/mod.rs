//! Budgeted adaptive search over the joint flow-variant space.
//!
//! The exhaustive explorer ([`crate::flow::explore`]) evaluates every
//! point of a spec's (task orders × CFG grid) — adding one grid value
//! multiplies runtime.  This subsystem makes the *selection* of points
//! pluggable: a [`SearchStrategy`] proposes batches of candidates,
//! observes their multi-objective results, and repeats until an
//! evaluation **budget** is exhausted, all on top of the same
//! [`crate::dse::ProbeService`]/[`crate::dse::ProbeTiers`] dedup machinery
//! the explorer uses (cf. MetaML-Pro's cross-stage search strategies
//! and the "Software-defined DSE" line of work: near-optimal fronts at
//! a fraction of the evaluations).
//!
//! Built-in strategies:
//!
//! | name         | behavior                                             |
//! |--------------|------------------------------------------------------|
//! | `exhaustive` | the full grid in declaration order (legacy explorer) |
//! | `random`     | seeded uniform sampling of the joint space           |
//! | `evolve`     | NSGA-II-style evolution (non-dominated sort +        |
//! |              | crowding; optional hardware-estimator prefilter)     |
//!
//! Specs opt in with a `search` section; the CLI can override it:
//!
//! ```json
//! "search": {
//!   "strategy": "evolve",
//!   "budget": 8,
//!   "seed": 7,
//!   "population": 4,
//!   "prefilter": true,
//!   "surrogate": {"warmup": 4, "every": 2},
//!   "range": {"hls.clock_period": {"min": 4.0, "max": 10.0}}
//! }
//! ```
//!
//! `range` adds numeric dimensions the samplers draw from
//! ([`RangeDim`]); `exhaustive` rejects them (no finite enumeration).
//! `surrogate` (`true` or an options object) turns on the online
//! learned predictor that answers dominated proposals without running
//! the flow ([`surrogate`]).  Determinism: for a fixed (spec, strategy,
//! seed, budget) the candidate sequence, every LOG event stream, and
//! the front are bit-identical for every `--jobs` value — with or
//! without the surrogate (its fit has a fixed feature order, fixed
//! observation order, and no RNG).

pub mod driver;
pub mod evolve;
pub mod exhaustive;
pub mod pareto;
pub mod prefilter;
pub mod random;
pub mod space;
pub mod surrogate;

pub use driver::{
    run_search, run_search_tiered, Observation, SearchCtx, SearchOutcome, SearchStrategy,
};
pub use evolve::Evolve;
pub use exhaustive::Exhaustive;
pub use prefilter::HwPrefilter;
pub use random::RandomSample;
pub use space::{Candidate, CandidateKey, RangeDim, SearchSpace};
pub use surrogate::{Surrogate, SurrogateReport, SurrogateSpec};

use crate::error::{Error, Result};
use crate::json::Value;

/// One way to order candidates best-first without running flows: the
/// hardware-estimator prefilter ranks by cheap estimator calls over
/// hardware-visible dimensions, the learned surrogate by predicted
/// NSGA order over the **full** candidate vector.  `Evolve`'s seed
/// pool and the driver's evaluation band share this seam.
pub trait CandidateRanker {
    /// Indices into `candidates`, best first.  Must be deterministic
    /// in the input order.
    fn rank(&self, space: &SearchSpace, candidates: &[Candidate]) -> Result<Vec<usize>>;
}

/// The built-in strategy names, in help/table order.
pub fn strategy_names() -> &'static [&'static str] {
    &["exhaustive", "random", "evolve"]
}

/// The parsed `search` section of a spec (or its CLI override).
#[derive(Debug, Clone)]
pub struct SearchSpec {
    /// One of [`strategy_names`].
    pub strategy: String,
    /// Evaluation budget (proposals); `None` = the discrete grid size.
    pub budget: Option<usize>,
    /// PRNG seed for the stochastic strategies.
    pub seed: u64,
    /// `evolve` population per generation (`None` = default).
    pub population: Option<usize>,
    /// Enable the cheap-estimator hardware prefilter.
    pub prefilter: bool,
    /// Enable the online learned surrogate (predicted-band evaluation
    /// policy in the driver).
    pub surrogate: Option<SurrogateSpec>,
    /// Numeric search dimensions (samplers only).
    pub ranges: Vec<(String, RangeDim)>,
    /// Pipelined probe scheduling: overlap flow execution with
    /// proposal/ranking by speculatively enqueuing likely next-round
    /// work on the persistent worker pool.  On by default — results
    /// are bit-identical either way (speculation only warms the probe
    /// tiers); `false` forces the lock-step barrier scheduler
    /// (benchmarked against in `benches/perf_runtime.rs`).
    pub pipeline: bool,
}

impl Default for SearchSpec {
    fn default() -> Self {
        SearchSpec {
            strategy: "exhaustive".into(),
            budget: None,
            seed: 0,
            population: None,
            prefilter: false,
            surrogate: None,
            ranges: Vec::new(),
            pipeline: true,
        }
    }
}

impl SearchSpec {
    /// Parse a spec's `search` object.  Unknown keys are rejected (a
    /// typo like `"buget"` must not silently run the default sweep).
    pub fn parse(v: &Value) -> Result<SearchSpec> {
        let Value::Object(map) = v else {
            return Err(Error::Config("\"search\" must be an object".into()));
        };
        let mut spec = SearchSpec::default();
        for (key, val) in map {
            match key.as_str() {
                "strategy" => {
                    let name = val.as_str().ok_or_else(|| {
                        Error::Config("search strategy must be a string".into())
                    })?;
                    if !strategy_names().contains(&name) {
                        return Err(Error::Config(format!(
                            "unknown search strategy {name:?} (expected one of: {})",
                            strategy_names().join(", ")
                        )));
                    }
                    spec.strategy = name.to_string();
                }
                "budget" => {
                    let b = val.as_usize().filter(|&b| b >= 1).ok_or_else(|| {
                        Error::Config("search budget must be a positive integer".into())
                    })?;
                    spec.budget = Some(b);
                }
                "seed" => {
                    spec.seed = val.as_usize().ok_or_else(|| {
                        Error::Config("search seed must be a non-negative integer".into())
                    })? as u64;
                }
                "population" => {
                    let p = val.as_usize().filter(|&p| p >= 2).ok_or_else(|| {
                        Error::Config("search population must be an integer >= 2".into())
                    })?;
                    spec.population = Some(p);
                }
                "prefilter" => {
                    spec.prefilter = val.as_bool().ok_or_else(|| {
                        Error::Config("search prefilter must be a bool".into())
                    })?;
                }
                "surrogate" => {
                    spec.surrogate = Some(SurrogateSpec::parse(val)?);
                }
                "pipeline" => {
                    spec.pipeline = val.as_bool().ok_or_else(|| {
                        Error::Config("search pipeline must be a bool".into())
                    })?;
                }
                "range" => {
                    let Value::Object(ranges) = val else {
                        return Err(Error::Config(
                            "search range must be an object of {key: {min, max}}".into(),
                        ));
                    };
                    for (rk, rv) in ranges {
                        spec.ranges.push((rk.clone(), RangeDim::parse(rk, rv)?));
                    }
                }
                other => {
                    return Err(Error::Config(format!(
                        "unknown search key {other:?} (valid: strategy, budget, seed, \
                         population, prefilter, surrogate, range, pipeline)"
                    )));
                }
            }
        }
        Ok(spec)
    }
}

/// Instantiate a strategy by name, validating it against the space
/// (`exhaustive` cannot sweep numeric ranges).
pub fn make_strategy(
    spec: &SearchSpec,
    space: &SearchSpace,
) -> Result<Box<dyn SearchStrategy>> {
    match spec.strategy.as_str() {
        "exhaustive" => {
            if !space.ranges.is_empty() {
                return Err(Error::Config(
                    "exhaustive search cannot enumerate numeric range dimensions \
                     (use strategy \"random\" or \"evolve\", or move the key into \
                     explore.cfg_grid)"
                        .into(),
                ));
            }
            Ok(Box::new(Exhaustive::new()))
        }
        "random" => Ok(Box::new(RandomSample::new(spec.seed))),
        "evolve" => Ok(Box::new(Evolve::new(spec.seed, spec.population))),
        other => Err(Error::Config(format!(
            "unknown search strategy {other:?} (expected one of: {})",
            strategy_names().join(", ")
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    #[test]
    fn parses_full_search_section() {
        let v = json::parse(
            r#"{"strategy": "evolve", "budget": 8, "seed": 7, "population": 4,
                "prefilter": true, "surrogate": {"warmup": 4, "every": 3},
                "range": {"hls.clock_period": {"min": 4.0, "max": 10.0}}}"#,
        )
        .unwrap();
        let s = SearchSpec::parse(&v).unwrap();
        assert_eq!(s.strategy, "evolve");
        assert_eq!(s.budget, Some(8));
        assert_eq!(s.seed, 7);
        assert_eq!(s.population, Some(4));
        assert!(s.prefilter);
        let sur = s.surrogate.as_ref().expect("surrogate parsed");
        assert_eq!(sur.warmup, Some(4));
        assert_eq!(sur.every, 3);
        assert_eq!(s.ranges.len(), 1);
        assert_eq!(s.ranges[0].0, "hls.clock_period");
        assert!(!s.ranges[0].1.integer);
    }

    #[test]
    fn defaults_are_exhaustive_full_grid() {
        let s = SearchSpec::parse(&json::parse("{}").unwrap()).unwrap();
        assert_eq!(s.strategy, "exhaustive");
        assert_eq!(s.budget, None);
        assert_eq!(s.seed, 0);
        assert!(!s.prefilter);
        assert!(s.surrogate.is_none());
        assert!(s.pipeline);
    }

    #[test]
    fn pipeline_parses_and_rejects_non_bools() {
        let s = SearchSpec::parse(&json::parse(r#"{"pipeline": false}"#).unwrap()).unwrap();
        assert!(!s.pipeline);
        let bad = SearchSpec::parse(&json::parse(r#"{"pipeline": 3}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(bad.contains("bool"), "{bad}");
    }

    #[test]
    fn surrogate_bool_true_enables_defaults() {
        let s = SearchSpec::parse(&json::parse(r#"{"surrogate": true}"#).unwrap()).unwrap();
        assert_eq!(s.surrogate, Some(SurrogateSpec::default()));
        let bad = SearchSpec::parse(&json::parse(r#"{"surrogate": {"bogus": 1}}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(bad.contains("bogus"), "{bad}");
    }

    #[test]
    fn rejects_unknown_strategies_keys_and_bad_values() {
        let bad = |s: &str| SearchSpec::parse(&json::parse(s).unwrap()).unwrap_err().to_string();
        assert!(bad(r#"{"strategy": "anneal"}"#).contains("anneal"));
        assert!(bad(r#"{"buget": 8}"#).contains("buget"));
        assert!(bad(r#"{"budget": 0}"#).contains("positive"));
        assert!(bad(r#"{"population": 1}"#).contains(">= 2"));
        assert!(bad(r#"{"range": {"x": {"min": 5, "max": 1}}}"#).contains("min < max"));
    }

    #[test]
    fn exhaustive_rejects_range_dimensions() {
        let spec = crate::config::FlowSpec::parse(
            r#"{"name": "t", "tasks": [{"id": "a", "type": "X"}], "edges": []}"#,
        )
        .unwrap();
        let search = SearchSpec {
            ranges: vec![("k".into(), RangeDim { lo: 0.0, hi: 1.0, integer: false })],
            ..Default::default()
        };
        let space = SearchSpace::of(&spec, &search.ranges).unwrap();
        let err = make_strategy(&search, &space).unwrap_err().to_string();
        assert!(err.contains("range"), "{err}");
        // the samplers accept the same space
        let mut random = SearchSpec { strategy: "random".into(), ..search.clone() };
        assert!(make_strategy(&random, &space).is_ok());
        random.strategy = "evolve".into();
        assert!(make_strategy(&random, &space).is_ok());
    }
}
