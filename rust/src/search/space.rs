//! The joint design space a budgeted search runs over.
//!
//! A [`SearchSpace`] is assembled from a spec's `explore` section (task
//! orders × discrete CFG grid) plus the `search` section's numeric
//! `range` dimensions.  A point in the space is a [`Candidate`] —
//! an order index, one index per discrete grid dimension, and one
//! value per numeric range dimension — which materializes into a
//! [`FlowVariant`] through the same label/graph construction the
//! exhaustive grid expander uses, so a strategy that happens to
//! enumerate the grid reproduces the legacy explorer bit-for-bit.
//!
//! Range dimensions are what distinguish samplers from the grid:
//! `Exhaustive` rejects them (there is no finite enumeration), while
//! `RandomSample`/`Evolve` draw real-valued (or integer) points from
//! them.

use crate::config::FlowSpec;
use crate::error::{Error, Result};
use crate::flow::explore::{variant_for, FlowVariant};
use crate::json::Value;
use crate::util::prng::Prng;

/// One numeric search dimension: a closed interval, optionally
/// integer-valued (samples are rounded and clamped).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RangeDim {
    pub lo: f64,
    pub hi: f64,
    pub integer: bool,
}

impl RangeDim {
    /// Parse `{"min": x, "max": y, "integer"?: bool}`.
    pub fn parse(key: &str, v: &Value) -> Result<RangeDim> {
        let lo = v.req_f64("min")?;
        let hi = v.req_f64("max")?;
        if !(lo < hi) || !lo.is_finite() || !hi.is_finite() {
            return Err(Error::Config(format!(
                "search range {key:?} needs finite min < max (got {lo}..{hi})"
            )));
        }
        let integer = match v.get("integer") {
            None => false,
            Some(b) => b.as_bool().ok_or_else(|| {
                Error::Config(format!("search range {key:?}: \"integer\" must be a bool"))
            })?,
        };
        // an integer interval must contain one, or snap()'s clamp onto
        // [ceil(lo), floor(hi)] would have min > max
        if integer && lo.ceil() > hi.floor() {
            return Err(Error::Config(format!(
                "search range {key:?} is integer but {lo}..{hi} contains no integer"
            )));
        }
        Ok(RangeDim { lo, hi, integer })
    }

    /// Clamp into the interval, rounding integer dimensions.
    pub fn snap(&self, x: f64) -> f64 {
        let x = x.clamp(self.lo, self.hi);
        if self.integer {
            x.round().clamp(self.lo.ceil(), self.hi.floor())
        } else {
            x
        }
    }

    /// Seeded uniform draw from the interval.
    pub fn sample(&self, prng: &mut Prng) -> f64 {
        self.snap(prng.uniform_in(self.lo, self.hi))
    }
}

/// One point of the joint space, in space-relative coordinates.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Index into [`SearchSpace::orders`].
    pub order: usize,
    /// Index per discrete grid dimension, aligned with
    /// [`SearchSpace::grid`].
    pub grid: Vec<usize>,
    /// Value per numeric dimension, aligned with
    /// [`SearchSpace::ranges`].
    pub range: Vec<f64>,
}

/// Hashable identity of a candidate (range values by bit pattern):
/// the dedup key for "has this exact point been evaluated".
pub type CandidateKey = (usize, Vec<usize>, Vec<u64>);

/// The search space: order choices, discrete grid dimensions, numeric
/// range dimensions.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Order permutations (`None` = the spec's own graph).  Always
    /// non-empty.
    pub orders: Vec<Option<Vec<String>>>,
    /// Discrete dimensions: CFG key → candidate values, in the
    /// `explore.cfg_grid` declaration (BTree) order.
    pub grid: Vec<(String, Vec<Value>)>,
    /// Numeric dimensions from the `search.range` section.
    pub ranges: Vec<(String, RangeDim)>,
}

impl SearchSpace {
    /// Assemble the space from a spec's `explore` grid and the search
    /// section's range dimensions.  A key may not be both a grid and a
    /// range dimension.
    pub fn of(spec: &FlowSpec, ranges: &[(String, RangeDim)]) -> Result<SearchSpace> {
        let explore = spec.explore.clone().unwrap_or_default();
        if !explore.orders.is_empty() {
            // same contract as expand_variants: order variants are plain
            // chains, so guards/back edges must not be silently dropped
            crate::flow::explore::reject_unchainable_orders(spec)?;
        }
        for (k, _) in ranges {
            if explore.cfg_grid.iter().any(|(g, _)| g == k) {
                return Err(Error::Config(format!(
                    "search range {k:?} collides with an explore cfg_grid dimension"
                )));
            }
        }
        let orders: Vec<Option<Vec<String>>> = if explore.orders.is_empty() {
            vec![None]
        } else {
            explore.orders.iter().cloned().map(Some).collect()
        };
        Ok(SearchSpace { orders, grid: explore.cfg_grid, ranges: ranges.to_vec() })
    }

    /// Size of the *discrete* part (orders × grid product) — what an
    /// exhaustive sweep evaluates and what budgets default to.  Range
    /// dimensions are uncountable and deliberately excluded.
    pub fn grid_size(&self) -> usize {
        self.orders.len() * self.grid.iter().map(|(_, vs)| vs.len()).product::<usize>()
    }

    /// Number of genome dimensions (order + grid + ranges).
    pub fn n_dims(&self) -> usize {
        1 + self.grid.len() + self.ranges.len()
    }

    /// Decode discrete grid point `i` (0 ≤ i < [`Self::grid_size`]) in
    /// exhaustive enumeration order — orders vary slowest, then grid
    /// dimensions in declaration order.  Range values are sampled from
    /// `prng` when dimensions exist (there is no canonical grid value
    /// for a continuous dimension).
    pub fn nth_grid_point(&self, i: usize, prng: &mut Prng) -> Candidate {
        debug_assert!(i < self.grid_size());
        let mut rem = i;
        let mut radix: Vec<usize> = vec![self.orders.len()];
        radix.extend(self.grid.iter().map(|(_, vs)| vs.len()));
        let mut digits = vec![0usize; radix.len()];
        for d in (0..radix.len()).rev() {
            digits[d] = rem % radix[d];
            rem /= radix[d];
        }
        Candidate {
            order: digits[0],
            grid: digits[1..].to_vec(),
            range: self.ranges.iter().map(|(_, r)| r.sample(prng)).collect(),
        }
    }

    /// Seeded uniform draw over the whole joint space.
    pub fn sample(&self, prng: &mut Prng) -> Candidate {
        Candidate {
            order: prng.below(self.orders.len()),
            grid: self.grid.iter().map(|(_, vs)| prng.below(vs.len())).collect(),
            range: self.ranges.iter().map(|(_, r)| r.sample(prng)).collect(),
        }
    }

    /// A candidate's dedup identity.
    pub fn key(&self, c: &Candidate) -> CandidateKey {
        (c.order, c.grid.clone(), c.range.iter().map(|v| v.to_bits()).collect())
    }

    /// The CFG overrides a candidate's coordinates decode to (grid
    /// dimensions first, then range dimensions, declaration order).
    pub fn candidate_cfg(&self, c: &Candidate) -> Vec<(String, Value)> {
        let mut cfg: Vec<(String, Value)> = self
            .grid
            .iter()
            .zip(&c.grid)
            .map(|((k, vs), &i)| (k.clone(), vs[i].clone()))
            .collect();
        cfg.extend(
            self.ranges
                .iter()
                .zip(&c.range)
                .map(|((k, _), &v)| (k.clone(), Value::Number(v))),
        );
        cfg
    }

    /// Materialize a candidate into a runnable [`FlowVariant`]
    /// (label-identical to grid expansion for pure-grid candidates).
    pub fn materialize(&self, spec: &FlowSpec, c: &Candidate) -> Result<FlowVariant> {
        variant_for(spec, self.orders[c.order].as_deref(), self.candidate_cfg(c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::explore::expand_variants;

    fn grid_spec() -> FlowSpec {
        FlowSpec::parse(
            r#"{"name": "t",
                "tasks": [{"id": "a", "type": "X"}, {"id": "b", "type": "Y"}],
                "edges": [["a", "b"]],
                "explore": {
                  "orders": [["a", "b"], ["b", "a"]],
                  "cfg_grid": {"k": [1, 2], "m": [10, 20, 30]}
                }}"#,
        )
        .unwrap()
    }

    #[test]
    fn enumeration_matches_exhaustive_grid_expansion() {
        let spec = grid_spec();
        let space = SearchSpace::of(&spec, &[]).unwrap();
        assert_eq!(space.grid_size(), 12);
        assert_eq!(space.n_dims(), 3);
        let expanded = expand_variants(&spec).unwrap();
        let mut prng = Prng::new(0);
        for i in 0..space.grid_size() {
            let c = space.nth_grid_point(i, &mut prng);
            let v = space.materialize(&spec, &c).unwrap();
            assert_eq!(v.label, expanded[i].label, "point {i}");
            assert_eq!(v.cfg, expanded[i].cfg, "point {i}");
        }
    }

    #[test]
    fn range_dims_parse_sample_and_snap() {
        let v = crate::json::parse(r#"{"min": 2.0, "max": 8.0, "integer": true}"#).unwrap();
        let dim = RangeDim::parse("x", &v).unwrap();
        let mut prng = Prng::new(3);
        for _ in 0..100 {
            let s = dim.sample(&mut prng);
            assert!((2.0..=8.0).contains(&s));
            assert_eq!(s.fract(), 0.0);
        }
        assert_eq!(dim.snap(7.4), 7.0);
        assert_eq!(dim.snap(100.0), 8.0);
        // min >= max rejected
        let bad = crate::json::parse(r#"{"min": 3.0, "max": 3.0}"#).unwrap();
        assert!(RangeDim::parse("x", &bad).is_err());
    }

    #[test]
    fn integer_range_must_contain_an_integer() {
        let v = crate::json::parse(r#"{"min": 2.1, "max": 2.9, "integer": true}"#).unwrap();
        let err = RangeDim::parse("x", &v).unwrap_err().to_string();
        assert!(err.contains("no integer"), "{err}");
    }

    #[test]
    fn orders_with_back_edges_rejected_like_grid_expansion() {
        // the search path must enforce the same plain-chain contract as
        // expand_variants instead of silently dropping the back edge
        let spec = FlowSpec::parse(
            r#"{"name": "t",
                "tasks": [{"id": "a", "type": "X"}, {"id": "b", "type": "Y"}],
                "edges": [["a", "b"]],
                "back_edges": [{"from": "b", "to": "a", "max_iters": 2}],
                "explore": {"orders": [["a", "b"], ["b", "a"]]}}"#,
        )
        .unwrap();
        let err = SearchSpace::of(&spec, &[]).unwrap_err().to_string();
        assert!(err.contains("back edges"), "{err}");
    }

    #[test]
    fn range_keys_may_not_collide_with_grid_keys() {
        let spec = grid_spec();
        let err = SearchSpace::of(
            &spec,
            &[("k".to_string(), RangeDim { lo: 0.0, hi: 1.0, integer: false })],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("collides"), "{err}");
    }

    #[test]
    fn candidate_keys_identify_exact_points() {
        let spec = grid_spec();
        let ranges = vec![("r".to_string(), RangeDim { lo: 0.0, hi: 1.0, integer: false })];
        let space = SearchSpace::of(&spec, &ranges).unwrap();
        let a = Candidate { order: 0, grid: vec![1, 2], range: vec![0.5] };
        let b = Candidate { order: 0, grid: vec![1, 2], range: vec![0.5] };
        assert_eq!(space.key(&a), space.key(&b));
        let c = Candidate { order: 0, grid: vec![1, 2], range: vec![0.25] };
        assert_ne!(space.key(&a), space.key(&c));
        // cfg decoding covers grid and range dims
        let cfg = space.candidate_cfg(&a);
        assert_eq!(cfg.len(), 3);
        assert_eq!(cfg[0].0, "k");
        assert_eq!(cfg[0].1.as_f64(), Some(2.0));
        assert_eq!(cfg[2], ("r".to_string(), Value::Number(0.5)));
    }
}
