//! The propose → evaluate → observe loop behind every search strategy.
//!
//! The driver owns everything a strategy must not: the evaluation
//! budget, the evaluated-candidate memo (an exact repeat is served from
//! memory, never re-run), variant materialization, the shared
//! [`ProbeTiers`] that dedupe training and hardware probes across the
//! whole search (and persist them, when a disk tier is attached), and
//! the final front.  A strategy only decides *which
//! points to look at next* — which is what makes the three built-ins
//! (and user strategies) interchangeable in specs and on the CLI.
//!
//! **Determinism contract** (same as the explorer's): for a fixed spec,
//! strategy, seed and budget, the sequence of evaluated candidates, all
//! their LOG event streams, and the reported front are bit-identical
//! for every `--jobs` value.  Strategies see only their own seeded PRNG
//! and the deterministic observations; worker counts change wall-clock
//! only.
//!
//! **Budget semantics:** `budget` bounds *proposals*.  Every candidate
//! a strategy proposes consumes one unit, including exact repeats of
//! already-evaluated points (a strategy that thrashes pays for it),
//! but a repeat costs no flow execution — it is observed from the memo.
//! An empty proposal batch ends the search early (space exhausted or
//! strategy converged).

use std::collections::HashMap;

use crate::config::FlowSpec;
use crate::dse::{ProbeCounts, ProbeTiers};
use crate::error::Result;
use crate::flow::explore::{run_variants, ExploreOutcome, FlowVariant};
use crate::flow::registry::TaskRegistry;
use crate::flow::session::Session;
use crate::json::Value;
use crate::search::pareto::pareto_front_min;
use crate::search::prefilter::HwPrefilter;
use crate::search::space::{Candidate, CandidateKey, SearchSpace};
use crate::search::{make_strategy, SearchSpec};

/// What the driver exposes to a strategy while it proposes/observes.
pub struct SearchCtx<'a> {
    pub space: &'a SearchSpace,
    /// Exact points already evaluated (key → index into the result
    /// list).  Strategies use it to avoid burning budget on repeats.
    pub evaluated: &'a HashMap<CandidateKey, usize>,
    /// Hardware-only candidate ranking, when the search enabled it and
    /// the session could build a baseline model.
    pub prefilter: Option<&'a HwPrefilter>,
}

/// One evaluated proposal, in proposal order.
#[derive(Debug, Clone)]
pub struct Observation {
    pub candidate: Candidate,
    pub label: String,
    /// Minimization objectives
    /// ([`crate::flow::VariantResult::min_objectives`]).
    pub objectives: Vec<f64>,
    /// True when the proposal repeated an already-evaluated point and
    /// was served from the memo.
    pub repeat: bool,
}

/// A pluggable multi-objective search strategy over the joint variant
/// space: propose a batch of candidates, observe their results, repeat
/// until the evaluation budget is exhausted.
pub trait SearchStrategy: Send {
    fn name(&self) -> &'static str;

    /// Propose up to `limit` candidates for the next evaluation batch
    /// (the driver truncates anything beyond it).  An empty batch ends
    /// the search.
    fn propose(&mut self, ctx: &SearchCtx<'_>, limit: usize) -> Result<Vec<Candidate>>;

    /// Observe the evaluated batch, in proposal order.
    fn observe(&mut self, ctx: &SearchCtx<'_>, batch: &[Observation]);
}

/// Everything one budgeted search produced.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Unique evaluated variants in evaluation order, plus the Pareto
    /// front over them — the same shape the exhaustive explorer
    /// reports, so tables/CSVs are shared.
    pub outcome: ExploreOutcome,
    pub strategy: String,
    /// Size of the discrete grid (what `Exhaustive` would evaluate).
    pub grid_size: usize,
    pub budget: usize,
    /// Proposals consumed (unique evaluations + repeats).
    pub spent: usize,
    /// Probe totals issued/computed through the search's shared pools.
    pub probes: ProbeCounts,
}

impl SearchOutcome {
    /// Unique flow evaluations actually run.
    pub fn evaluations(&self) -> usize {
        self.outcome.results.len()
    }
}

/// Run a budgeted search over `spec`'s joint variant space.
///
/// `extra_cfg` is applied to every variant (CLI `--model` / `-c`
/// overrides); `jobs` bounds concurrently running variants per batch
/// exactly like [`crate::flow::explore::explore_variants`].
pub fn run_search(
    session: &Session,
    registry: &TaskRegistry,
    spec: &FlowSpec,
    search: &SearchSpec,
    extra_cfg: &[(String, Value)],
    jobs: usize,
) -> Result<SearchOutcome> {
    run_search_tiered(session, registry, spec, search, extra_cfg, jobs, &ProbeTiers::new())
}

/// [`run_search`] against caller-provided probe tiers — how the CLI
/// attaches a persistent `--cache-dir` disk tier, and the seam for
/// pointing a search at any other [`crate::dse::ProbeService`] backing.
pub fn run_search_tiered(
    session: &Session,
    registry: &TaskRegistry,
    spec: &FlowSpec,
    search: &SearchSpec,
    extra_cfg: &[(String, Value)],
    jobs: usize,
    tiers: &ProbeTiers,
) -> Result<SearchOutcome> {
    let space = SearchSpace::of(spec, &search.ranges)?;
    let grid_size = space.grid_size();
    let budget = search.budget.unwrap_or(grid_size).max(1);
    let mut strategy = make_strategy(search, &space)?;
    let shared = tiers.clone();
    let prefilter = if search.prefilter {
        // heuristic accelerator: a session whose manifest can't model
        // the spec (no such variant) just runs without it
        HwPrefilter::build(session, spec, extra_cfg, &shared, jobs).ok()
    } else {
        None
    };

    let mut results = Vec::new();
    let mut objectives: Vec<Vec<f64>> = Vec::new();
    let mut index: HashMap<CandidateKey, usize> = HashMap::new();
    let mut spent = 0usize;
    while spent < budget {
        let batch = {
            let ctx = SearchCtx {
                space: &space,
                evaluated: &index,
                prefilter: prefilter.as_ref(),
            };
            strategy.propose(&ctx, budget - spent)?
        };
        if batch.is_empty() {
            break;
        }
        let batch = &batch[..batch.len().min(budget - spent)];
        spent += batch.len();

        // resolve each proposal: repeats (incl. batch-internal ones)
        // are served from the memo, first appearances get the next
        // result slot, all in proposal order
        let prior = results.len();
        let mut slots: Vec<(usize, bool)> = Vec::with_capacity(batch.len());
        let mut fresh: Vec<FlowVariant> = Vec::new();
        for c in batch {
            match index.get(&space.key(c)) {
                Some(&slot) => slots.push((slot, true)),
                None => {
                    let slot = prior + fresh.len();
                    index.insert(space.key(c), slot);
                    fresh.push(space.materialize(spec, c)?);
                    slots.push((slot, false));
                }
            }
        }
        let ran = run_variants(session, registry, &fresh, extra_cfg, jobs, &shared)?;
        for r in ran {
            objectives.push(r.min_objectives()?);
            results.push(r);
        }

        let observations: Vec<Observation> = batch
            .iter()
            .zip(&slots)
            .map(|(c, &(slot, repeat))| Observation {
                candidate: c.clone(),
                label: results[slot].label.clone(),
                objectives: objectives[slot].clone(),
                repeat,
            })
            .collect();
        let ctx = SearchCtx {
            space: &space,
            evaluated: &index,
            prefilter: prefilter.as_ref(),
        };
        strategy.observe(&ctx, &observations);
    }

    let front = pareto_front_min(&objectives);
    Ok(SearchOutcome {
        outcome: ExploreOutcome { results, front },
        strategy: strategy.name().to_string(),
        grid_size,
        budget,
        spent,
        probes: shared.probe_counts(),
    })
}
