//! The propose → evaluate → observe loop behind every search strategy.
//!
//! The driver owns everything a strategy must not: the evaluation
//! budget, the evaluated-candidate memo (an exact repeat is served from
//! memory, never re-run), variant materialization, the shared
//! [`ProbeTiers`] that dedupe training and hardware probes across the
//! whole search (and persist them, when a disk tier is attached), and
//! the final front.  A strategy only decides *which
//! points to look at next* — which is what makes the three built-ins
//! (and user strategies) interchangeable in specs and on the CLI.
//!
//! **Determinism contract** (same as the explorer's): for a fixed spec,
//! strategy, seed and budget, the sequence of evaluated candidates, all
//! their LOG event streams, and the reported front are bit-identical
//! for every `--jobs` value.  Strategies see only their own seeded PRNG
//! and the deterministic observations; worker counts change wall-clock
//! only.  The surrogate policy below preserves this: its fit, its
//! predictions and every defer/evaluate decision are pure functions of
//! the evaluation history, which is itself deterministic.
//!
//! **Budget semantics:** `budget` bounds *proposals*.  Every candidate
//! a strategy proposes consumes one unit, including exact repeats of
//! already-evaluated points (a strategy that thrashes pays for it),
//! but a repeat costs no flow execution — it is observed from the memo.
//! An empty proposal batch ends the search early (space exhausted or
//! strategy converged).
//!
//! **Surrogate policy** (`search.surrogate`): with the online learned
//! predictor enabled ([`crate::search::surrogate`]), the driver first
//! spends part of the budget on a space-filling **warmup** (a strided
//! sample of the grid enumeration, so every dimension shows variance
//! before the model is trusted).  After that, each fresh proposal is
//! predicted before it is run: a candidate whose prediction — granted
//! a trust-radius optimism margin — is still dominated by an evaluated
//! point is **deferred** (the strategy observes the predicted
//! objectives, flagged `predicted`; no flow runs, no training probes
//! are spent).  Deferred candidates are periodically re-validated
//! (best-predicted first), and at the end every deferred candidate
//! whose re-prediction is not dominated by the truth set is evaluated
//! — the reported results and front contain **only truth**, never
//! predictions.

use std::collections::{HashMap, HashSet};

use crate::config::FlowSpec;
use crate::dse::{submit_batch, ProbeCounts, ProbeService, ProbeTiers, SubmittedBatch};
use crate::error::{Error, Result};
use crate::flow::explore::{
    run_one_variant, run_variants, ExploreOutcome, FlowVariant, VariantResult,
};
use crate::flow::registry::TaskRegistry;
use crate::flow::session::Session;
use crate::json::Value;
use crate::obs::{metrics, trace};
use crate::search::pareto::{dominates_min, nsga_order, pareto_front_min};
use crate::search::prefilter::HwPrefilter;
use crate::search::space::{Candidate, CandidateKey, SearchSpace};
use crate::search::surrogate::{Surrogate, SurrogateReport};
use crate::search::{make_strategy, CandidateRanker, SearchSpec};
use crate::util::prng::Prng;

/// Seed salt for the warmup sampler's range draws — forked from the
/// search seed so the strategy's own PRNG stream is untouched by
/// enabling the surrogate.
const WARMUP_SEED_SALT: u64 = 0x5u64.wrapping_mul(0x9e37_79b9_7f4a_7c15);

/// What the driver exposes to a strategy while it proposes/observes.
pub struct SearchCtx<'a> {
    pub space: &'a SearchSpace,
    /// Exact points already evaluated (key → index into the result
    /// list).  Strategies use it to avoid burning budget on repeats.
    pub evaluated: &'a HashMap<CandidateKey, usize>,
    /// Points answered by surrogate prediction instead of a flow run
    /// (key → deferred-pool index).  Empty unless `search.surrogate`
    /// is enabled; strategies should treat them like evaluated points
    /// when hunting for fresh proposals.
    pub deferred: &'a HashMap<CandidateKey, usize>,
    /// Best-first candidate ranking without flow runs: the fitted
    /// surrogate once it is ready, else the hardware prefilter when
    /// the search enabled it and the session could build a baseline
    /// model.
    pub ranker: Option<&'a dyn CandidateRanker>,
}

/// One evaluated proposal, in proposal order.
#[derive(Debug, Clone)]
pub struct Observation {
    pub candidate: Candidate,
    pub label: String,
    /// Minimization objectives
    /// ([`crate::flow::VariantResult::min_objectives`]).
    pub objectives: Vec<f64>,
    /// True when the proposal repeated an already-seen point and was
    /// served from the memo (or the deferred pool).
    pub repeat: bool,
    /// True when `objectives` are surrogate predictions, not a flow
    /// run.  A later truth evaluation of the same candidate arrives as
    /// a fresh non-predicted observation.
    pub predicted: bool,
}

/// A pluggable multi-objective search strategy over the joint variant
/// space: propose a batch of candidates, observe their results, repeat
/// until the evaluation budget is exhausted.
pub trait SearchStrategy: Send {
    fn name(&self) -> &'static str;

    /// Propose up to `limit` candidates for the next evaluation batch
    /// (the driver truncates anything beyond it).  An empty batch ends
    /// the search.
    fn propose(&mut self, ctx: &SearchCtx<'_>, limit: usize) -> Result<Vec<Candidate>>;

    /// Observe the evaluated batch, in proposal order.
    fn observe(&mut self, ctx: &SearchCtx<'_>, batch: &[Observation]);

    /// Guess what the next [`Self::propose`] call will return,
    /// **without consuming any strategy state** — no PRNG draws, no
    /// archive mutation (clone whatever state the guess needs).  The
    /// pipelined scheduler enqueues these on the persistent worker
    /// pool while the current round is still being observed; a wrong
    /// guess only warms the shared probe tiers (cache fodder), so
    /// guesses can never alter the observed trace.  The default
    /// guesses nothing (no speculation).
    fn speculate(&self, _ctx: &SearchCtx<'_>) -> Vec<Candidate> {
        Vec::new()
    }
}

/// Everything one budgeted search produced.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    /// Unique evaluated variants in evaluation order, plus the Pareto
    /// front over them — the same shape the exhaustive explorer
    /// reports, so tables/CSVs are shared.  Truth only: deferred
    /// candidates never appear here.
    pub outcome: ExploreOutcome,
    pub strategy: String,
    /// Size of the discrete grid (what `Exhaustive` would evaluate).
    pub grid_size: usize,
    pub budget: usize,
    /// Proposals consumed (unique evaluations + repeats + deferrals).
    pub spent: usize,
    /// Probe totals issued/computed through the search's shared pools.
    pub probes: ProbeCounts,
    /// Surrogate accounting, when `search.surrogate` was enabled.
    pub surrogate: Option<SurrogateReport>,
    /// Wall-clock seconds the whole search took (a diagnostic, never
    /// replay-comparable).
    pub wall_secs: f64,
}

/// The cost/efficiency bundle the explore summary and
/// [`crate::flow::explore::front_csv`] surface alongside the front.
#[derive(Debug, Clone, Default)]
pub struct SearchCost {
    pub probes: ProbeCounts,
    pub grid_size: usize,
    pub budget: usize,
    pub spent: usize,
    pub surrogate: Option<SurrogateReport>,
    /// Wall-clock seconds; `0.0` means "untimed" (blank CSV columns).
    pub wall_secs: f64,
}

impl SearchOutcome {
    /// Unique flow evaluations actually run.
    pub fn evaluations(&self) -> usize {
        self.outcome.results.len()
    }

    pub fn cost(&self) -> SearchCost {
        SearchCost {
            probes: self.probes,
            grid_size: self.grid_size,
            budget: self.budget,
            spent: self.spent,
            surrogate: self.surrogate.clone(),
            wall_secs: self.wall_secs,
        }
    }
}

/// A proposal answered by prediction instead of a flow run.
struct DeferredEntry {
    candidate: Candidate,
    label: String,
    /// The prediction that justified the deferral (what the strategy
    /// observed, and what error feedback is measured against).
    predicted: Vec<f64>,
    validated: bool,
}

/// The ranker strategies see: the fitted surrogate wins once ready
/// (it models the full candidate vector), else the hardware prefilter.
fn ranker_of<'a>(
    surrogate: &'a Option<Surrogate>,
    prefilter: &'a Option<HwPrefilter>,
) -> Option<&'a dyn CandidateRanker> {
    match surrogate {
        Some(s) if s.ready() => Some(s as &dyn CandidateRanker),
        _ => prefilter.as_ref().map(|p| p as &dyn CandidateRanker),
    }
}

/// The driver's flow-execution seam: every truth evaluation goes
/// through here, so the pipelined scheduler has one place to overlap
/// flow runs with proposal/observation work.
///
/// Two modes, chosen once per search:
///
/// * **barrier** (`pipeline: false`, or `jobs == 1`): each batch runs
///   through [`run_variants`] and the driver blocks until it is done —
///   the pre-pipelining behavior, bit for bit.
/// * **pipelined**: [`Self::speculate`] enqueues *guessed* next-round
///   candidates on the persistent worker pool (via the
///   [`ProbeService`] async seam) while the driver is still observing
///   the current round; [`Self::eval`] then commits results **in
///   proposal order** — a speculation hit is awaited where the
///   proposal sits, a miss is submitted on the spot.  Mis-speculated
///   runs are never observed: [`Self::finish`] waits them out so their
///   probes land in the shared tiers as cache fodder (or cancels them
///   before they start, mid-search, when the guess set moves on).
///
/// Because every flow run is a pure function of its variant and the
/// observed trace commits strictly in proposal order, the candidate
/// sequence, LOG streams, front, and surrogate accounting are
/// bit-identical in both modes; only the `spec_*` wall-clock counters
/// differ.
struct FlowRunner<'a> {
    session: &'a Session,
    registry: &'a TaskRegistry,
    extra_cfg: &'a [(String, Value)],
    jobs: usize,
    shared: &'a ProbeTiers,
    svc: &'a dyn ProbeService,
    pipeline: bool,
    /// In-flight speculative single-variant batches, keyed by
    /// candidate.  Capacity-capped at `jobs`.
    pending: HashMap<CandidateKey, SubmittedBatch<'a, VariantResult>>,
}

impl<'a> FlowRunner<'a> {
    /// Submit one candidate's flow on the worker pool without waiting.
    fn submit(&self, variant: FlowVariant) -> SubmittedBatch<'a, VariantResult> {
        let (session, registry) = (self.session, self.registry);
        let (extra_cfg, shared) = (self.extra_cfg, self.shared);
        submit_batch(self.svc, 1, move |_| {
            // inner_jobs = 1: pipelined variants already saturate the
            // pool across each other, exactly like a full barrier batch
            run_one_variant(session, registry, &variant, extra_cfg, 1, shared)
        })
    }

    /// Speculatively enqueue `guesses` (already filtered against the
    /// evaluated memo).  Stale pending guesses that fell out of the
    /// set are cancelled when they have not started; started ones stay
    /// pending as cache fodder.  No-op in barrier mode.
    fn speculate(&mut self, spec: &FlowSpec, space: &SearchSpace, guesses: &[Candidate]) {
        if !self.pipeline || guesses.is_empty() {
            return;
        }
        let keep: HashSet<CandidateKey> = guesses.iter().map(|c| space.key(c)).collect();
        let stale: Vec<CandidateKey> =
            self.pending.keys().filter(|k| !keep.contains(*k)).cloned().collect();
        for key in stale {
            let mut batch = self.pending.remove(&key).expect("stale key is pending");
            if batch.try_cancel() {
                self.shared.stats.note_speculation_cancelled();
            } else {
                // already running — let it finish into the tiers
                self.pending.insert(key, batch);
            }
        }
        for c in guesses {
            if self.pending.len() >= self.jobs {
                break;
            }
            let key = space.key(c);
            if self.pending.contains_key(&key) {
                continue;
            }
            // a candidate that cannot materialize would fail its real
            // evaluation too — let that path report the error
            let Ok(variant) = space.materialize(spec, c) else { continue };
            self.shared.stats.note_speculation_submitted();
            let batch = self.submit(variant);
            self.pending.insert(key, batch);
        }
    }

    /// Truth-evaluate `cands` (unique, never before evaluated) and
    /// append their results/objectives in proposal order.
    fn eval(
        &mut self,
        space: &SearchSpace,
        spec: &FlowSpec,
        cands: &[Candidate],
        results: &mut Vec<VariantResult>,
        objectives: &mut Vec<Vec<f64>>,
    ) -> Result<()> {
        if cands.is_empty() {
            return Ok(());
        }
        if !self.pipeline {
            let fresh: Vec<FlowVariant> =
                cands.iter().map(|c| space.materialize(spec, c)).collect::<Result<_>>()?;
            let ran = run_variants(
                self.session, self.registry, &fresh, self.extra_cfg, self.jobs, self.shared,
            )?;
            for r in ran {
                objectives.push(r.min_objectives()?);
                results.push(r);
            }
            return Ok(());
        }
        // commit order = proposal order: hits are consumed in place,
        // misses submitted up front so they overlap the hits' waits
        let mut waits: Vec<SubmittedBatch<'a, VariantResult>> =
            Vec::with_capacity(cands.len());
        for c in cands {
            let key = space.key(c);
            match self.pending.remove(&key) {
                Some(batch) => {
                    self.shared.stats.note_speculation_committed();
                    waits.push(batch);
                }
                None => waits.push(self.submit(space.materialize(spec, c)?)),
            }
        }
        for batch in waits {
            let mut ran = batch.wait()?;
            let r = ran.pop().ok_or_else(|| {
                Error::Flow("probe scheduler: empty single-variant batch".into())
            })?;
            objectives.push(r.min_objectives()?);
            results.push(r);
        }
        Ok(())
    }

    /// Wait out every still-pending speculative run (never cancel:
    /// deterministic cache contents for a deterministic guess stream)
    /// so its probes land in the shared tiers before counters are
    /// snapshotted.
    fn finish(&mut self) {
        for (_, batch) in self.pending.drain() {
            drop(batch); // Drop waits
        }
    }
}

/// Truth-evaluate one deferred candidate: run the flow, move its key
/// from the deferred pool to the evaluated memo, feed the prediction
/// error back into the trust radius, teach the surrogate the truth,
/// and let the strategy observe the corrected objectives.
#[allow(clippy::too_many_arguments)]
fn validate_deferred(
    idx: usize,
    exec: &mut FlowRunner<'_>,
    spec: &FlowSpec,
    space: &SearchSpace,
    surrogate: &mut Surrogate,
    strategy: &mut dyn SearchStrategy,
    deferred: &mut [DeferredEntry],
    deferred_index: &mut HashMap<CandidateKey, usize>,
    index: &mut HashMap<CandidateKey, usize>,
    results: &mut Vec<VariantResult>,
    objectives: &mut Vec<Vec<f64>>,
) -> Result<()> {
    let mut span = trace::span("search", "search.validate");
    span.arg("label", deferred[idx].label.as_str());
    let candidate = deferred[idx].candidate.clone();
    let key = space.key(&candidate);
    let slot = results.len();
    exec.eval(space, spec, std::slice::from_ref(&candidate), results, objectives)?;
    deferred[idx].validated = true;
    deferred_index.remove(&key);
    index.insert(key, slot);
    surrogate.note_validated();
    surrogate.record_error(&deferred[idx].predicted, &objectives[slot], objectives);
    surrogate.observe_truth(&candidate, &objectives[slot]);
    surrogate.fit_if_dirty();
    let obs = Observation {
        candidate,
        label: results[slot].label.clone(),
        objectives: objectives[slot].clone(),
        repeat: false,
        predicted: false,
    };
    let ctx = SearchCtx { space, evaluated: index, deferred: deferred_index, ranker: None };
    strategy.observe(&ctx, &[obs]);
    Ok(())
}

/// Best-predicted pending deferral (NSGA order over fresh
/// re-predictions), if any.
fn top_deferred(surrogate: &Surrogate, deferred: &[DeferredEntry]) -> Option<usize> {
    let pending: Vec<usize> = deferred
        .iter()
        .enumerate()
        .filter(|(_, d)| !d.validated)
        .map(|(i, _)| i)
        .collect();
    if pending.is_empty() {
        return None;
    }
    let preds: Vec<Vec<f64>> =
        pending.iter().map(|&i| surrogate.predict(&deferred[i].candidate)).collect();
    let order = nsga_order(&preds);
    Some(pending[order[0]])
}

/// Run a budgeted search over `spec`'s joint variant space.
///
/// `extra_cfg` is applied to every variant (CLI `--model` / `-c`
/// overrides); `jobs` bounds concurrently running variants per batch
/// exactly like [`crate::flow::explore::explore_variants`].
pub fn run_search(
    session: &Session,
    registry: &TaskRegistry,
    spec: &FlowSpec,
    search: &SearchSpec,
    extra_cfg: &[(String, Value)],
    jobs: usize,
) -> Result<SearchOutcome> {
    run_search_tiered(session, registry, spec, search, extra_cfg, jobs, &ProbeTiers::new())
}

/// [`run_search`] against caller-provided probe tiers — how the CLI
/// attaches a persistent `--cache-dir` disk tier, and the seam for
/// pointing a search at any other [`crate::dse::ProbeService`] backing.
pub fn run_search_tiered(
    session: &Session,
    registry: &TaskRegistry,
    spec: &FlowSpec,
    search: &SearchSpec,
    extra_cfg: &[(String, Value)],
    jobs: usize,
    tiers: &ProbeTiers,
) -> Result<SearchOutcome> {
    // wall clock lives in the metrics registry (satellite of the obs
    // subsystem), not in driver-local Instant plumbing
    let timer = metrics::start_timer("search.wall_secs");
    let space = SearchSpace::of(spec, &search.ranges)?;
    let grid_size = space.grid_size();
    let budget = search.budget.unwrap_or(grid_size).max(1);
    let mut strategy = make_strategy(search, &space)?;
    let mut search_span = trace::span("search", "search.run");
    search_span.arg("strategy", strategy.name());
    search_span.arg("budget", budget);
    search_span.arg("grid_size", grid_size);
    let shared = tiers.clone();
    // declared before `exec` so the service outlives the batches that
    // borrow it (drop order is reverse declaration order)
    let svc: std::sync::Arc<dyn ProbeService> = shared.service(jobs);
    let prefilter = if search.prefilter {
        // heuristic accelerator: a session whose manifest can't model
        // the spec (no such variant) just runs without it
        HwPrefilter::build(session, spec, extra_cfg, &shared, jobs).ok()
    } else {
        None
    };
    let mut surrogate = search
        .surrogate
        .as_ref()
        .map(|s| Surrogate::new(&space, s, std::sync::Arc::clone(&shared.stats)));
    let mut exec = FlowRunner {
        session,
        registry,
        extra_cfg,
        jobs,
        shared: &shared,
        svc: &*svc,
        // jobs == 1 has nothing to overlap with — take the exact
        // barrier path (and its inline fast paths)
        pipeline: search.pipeline && jobs > 1,
        pending: HashMap::new(),
    };

    let mut results: Vec<VariantResult> = Vec::new();
    let mut objectives: Vec<Vec<f64>> = Vec::new();
    let mut index: HashMap<CandidateKey, usize> = HashMap::new();
    let mut deferred: Vec<DeferredEntry> = Vec::new();
    let mut deferred_index: HashMap<CandidateKey, usize> = HashMap::new();
    let mut spent = 0usize;

    // ---- warmup: a driver-owned, space-filling strided sample ------
    // Front-seeking proposals concentrate on the best-known region and
    // can leave a dimension with zero variance (every point at the
    // same clock), which no fit can learn from.  Striding the grid
    // enumeration guarantees coverage; range dimensions draw from a
    // PRNG forked off the search seed so the strategy's stream is
    // untouched.
    if let Some(sur) = surrogate.as_mut() {
        let _warmup_span = trace::span("search", "search.warmup");
        let want = sur.warmup().min(budget);
        let mut prng = Prng::new(search.seed ^ WARMUP_SEED_SALT);
        let mut picks: Vec<Candidate> = Vec::new();
        for i in 0..want {
            let at = if want >= grid_size { i % grid_size } else { i * grid_size / want };
            let c = space.nth_grid_point(at, &mut prng);
            let key = space.key(&c);
            if index.contains_key(&key) {
                continue;
            }
            index.insert(key, picks.len());
            picks.push(c);
        }
        if !picks.is_empty() {
            spent += picks.len();
            exec.eval(&space, spec, &picks, &mut results, &mut objectives)?;
            let observations: Vec<Observation> = picks
                .iter()
                .enumerate()
                .map(|(slot, c)| {
                    sur.observe_truth(c, &objectives[slot]);
                    Observation {
                        candidate: c.clone(),
                        label: results[slot].label.clone(),
                        objectives: objectives[slot].clone(),
                        repeat: false,
                        predicted: false,
                    }
                })
                .collect();
            let ctx =
                SearchCtx { space: &space, evaluated: &index, deferred: &deferred_index, ranker: None };
            strategy.observe(&ctx, &observations);
        }
        sur.finish_warmup();
        sur.fit_if_dirty();
    }

    // ---- propose → gate → evaluate → observe -----------------------
    let mut rounds = 0usize;
    while spent < budget {
        let mut round_span = trace::span("search", "search.round");
        round_span.arg("round", rounds);
        // pipelined: guess the upcoming batch *before* the real
        // propose call (the strategy's PRNG sits at the same point the
        // clone-based guess needs) and enqueue it on the worker pool;
        // pending deferrals ride along since a re-validation may pick
        // any of them next.  Wrong guesses only warm the tiers.
        if exec.pipeline {
            let mut guesses = {
                // ranker withheld: guessing must not spend counted
                // surrogate/prefilter queries
                let ctx = SearchCtx {
                    space: &space,
                    evaluated: &index,
                    deferred: &deferred_index,
                    ranker: None,
                };
                strategy.speculate(&ctx)
            };
            guesses.retain(|c| {
                let key = space.key(c);
                !index.contains_key(&key) && !deferred_index.contains_key(&key)
            });
            for d in deferred.iter().filter(|d| !d.validated) {
                guesses.push(d.candidate.clone());
            }
            exec.speculate(spec, &space, &guesses);
        }
        let batch = {
            let _span = trace::span("search", "search.propose");
            let ctx = SearchCtx {
                space: &space,
                evaluated: &index,
                deferred: &deferred_index,
                ranker: ranker_of(&surrogate, &prefilter),
            };
            strategy.propose(&ctx, budget - spent)?
        };
        if batch.is_empty() {
            break;
        }
        let batch = &batch[..batch.len().min(budget - spent)];
        spent += batch.len();
        rounds += 1;

        // resolve each proposal in order: evaluated repeats from the
        // memo, deferred repeats re-served their prediction, fresh
        // points either deferred (prediction dominated even with the
        // optimism margin) or slotted for a real run
        enum Slot {
            Truth { slot: usize, repeat: bool },
            Predicted { idx: usize, repeat: bool },
        }
        let prior = results.len();
        let mut slots: Vec<Slot> = Vec::with_capacity(batch.len());
        let mut fresh_cands: Vec<Candidate> = Vec::new();
        let mut band_preds: Vec<(usize, Vec<f64>)> = Vec::new();
        for c in batch {
            let key = space.key(c);
            if let Some(&slot) = index.get(&key) {
                slots.push(Slot::Truth { slot, repeat: true });
                continue;
            }
            if let Some(&idx) = deferred_index.get(&key) {
                slots.push(Slot::Predicted { idx, repeat: true });
                continue;
            }
            if let Some(sur) = surrogate.as_mut().filter(|s| s.ready()) {
                let pred = sur.predict(c);
                if sur.defer(&pred, &objectives) {
                    sur.note_deferred();
                    let idx = deferred.len();
                    deferred_index.insert(key, idx);
                    deferred.push(DeferredEntry {
                        candidate: c.clone(),
                        label: space.materialize(spec, c)?.label,
                        predicted: pred,
                        validated: false,
                    });
                    slots.push(Slot::Predicted { idx, repeat: false });
                    continue;
                }
                // predicted-front band: worth a real evaluation; keep
                // the prediction to score the model once truth lands
                band_preds.push((prior + fresh_cands.len(), pred));
            }
            let slot = prior + fresh_cands.len();
            index.insert(key, slot);
            fresh_cands.push(c.clone());
            slots.push(Slot::Truth { slot, repeat: false });
        }
        {
            let mut span = trace::span("search", "search.eval");
            span.arg("fresh", fresh_cands.len());
            exec.eval(&space, spec, &fresh_cands, &mut results, &mut objectives)?;
        }
        if let Some(sur) = surrogate.as_mut() {
            for (slot, pred) in &band_preds {
                sur.record_error(pred, &objectives[*slot], &objectives);
            }
            for (i, c) in fresh_cands.iter().enumerate() {
                sur.observe_truth(c, &objectives[prior + i]);
            }
            sur.fit_if_dirty();
        }

        let observations: Vec<Observation> = batch
            .iter()
            .zip(&slots)
            .map(|(c, slot)| match *slot {
                Slot::Truth { slot, repeat } => Observation {
                    candidate: c.clone(),
                    label: results[slot].label.clone(),
                    objectives: objectives[slot].clone(),
                    repeat,
                    predicted: false,
                },
                Slot::Predicted { idx, repeat } => Observation {
                    candidate: c.clone(),
                    label: deferred[idx].label.clone(),
                    objectives: deferred[idx].predicted.clone(),
                    repeat,
                    predicted: true,
                },
            })
            .collect();
        {
            let _span = trace::span("search", "search.observe");
            let ctx = SearchCtx {
                space: &space,
                evaluated: &index,
                deferred: &deferred_index,
                ranker: ranker_of(&surrogate, &prefilter),
            };
            strategy.observe(&ctx, &observations);
        }

        // periodic re-validation: every K rounds the best-predicted
        // deferral is truth-evaluated (spending one of the flows the
        // deferral saved), so a drifting model is caught mid-search,
        // not only at the end
        if let Some(sur) = surrogate.as_mut() {
            if sur.ready() && rounds % sur.every() == 0 {
                if let Some(idx) = top_deferred(sur, &deferred) {
                    validate_deferred(
                        idx, &mut exec, spec, &space, sur, strategy.as_mut(), &mut deferred,
                        &mut deferred_index, &mut index, &mut results, &mut objectives,
                    )?;
                }
            }
        }
    }

    // ---- final validation: the front may not rest on predictions ---
    // Re-predict every pending deferral with the final model; any not
    // strictly dominated by an evaluated point gets truth-evaluated
    // (best-predicted first, so each run can dominate away the rest).
    // Every iteration shrinks the pending pool by one, so this
    // terminates; on a hostile space it degrades to evaluating all
    // deferrals — exhaustive behavior, never a wrong front.
    if exec.pipeline && surrogate.is_some() {
        // any pending deferral may be validated below — warm them all
        // (capacity-capped) while the first re-prediction round runs
        let guesses: Vec<Candidate> = deferred
            .iter()
            .filter(|d| !d.validated)
            .map(|d| d.candidate.clone())
            .collect();
        exec.speculate(spec, &space, &guesses);
    }
    while let Some(sur) = surrogate.as_mut() {
        let next = {
            let pending: Vec<usize> = deferred
                .iter()
                .enumerate()
                .filter(|(_, d)| !d.validated)
                .map(|(i, _)| i)
                .collect();
            let live: Vec<(usize, Vec<f64>)> = pending
                .iter()
                .map(|&i| (i, sur.predict(&deferred[i].candidate)))
                .filter(|(_, p)| !objectives.iter().any(|t| dominates_min(t, p)))
                .collect();
            if live.is_empty() {
                None
            } else {
                let preds: Vec<Vec<f64>> = live.iter().map(|(_, p)| p.clone()).collect();
                Some(live[nsga_order(&preds)[0]].0)
            }
        };
        match next {
            Some(idx) => validate_deferred(
                idx, &mut exec, spec, &space, sur, strategy.as_mut(), &mut deferred,
                &mut deferred_index, &mut index, &mut results, &mut objectives,
            )?,
            None => break,
        }
    }

    // drain mis-speculated runs into the tiers before the counters are
    // snapshotted, so cache contents and probe totals are settled
    exec.finish();
    let wall_secs = timer.stop();
    let probes = shared.probe_counts();
    metrics::bridge_probe_counts(&probes);

    let front = pareto_front_min(&objectives);
    Ok(SearchOutcome {
        outcome: ExploreOutcome { results, front },
        strategy: strategy.name().to_string(),
        grid_size,
        budget,
        spent,
        probes,
        surrogate: surrogate.as_ref().map(Surrogate::report),
        wall_secs,
    })
}
