//! `RandomSample`: seeded uniform sampling of the joint space.
//!
//! The baseline budgeted strategy: every proposal is an independent
//! uniform draw over (orders × grid × ranges) from the run's seeded
//! [`Prng`], with bounded rejection of points it already proposed or
//! the driver already evaluated (so small discrete spaces don't burn
//! the whole budget on repeats, while a genuinely exhausted space still
//! terminates by paying for one).  No adaptation — it exists as the
//! statistical control `evolve` must beat, and as the simplest way to
//! sample range dimensions at all.

use std::collections::HashSet;

use crate::error::Result;
use crate::search::driver::{Observation, SearchCtx, SearchStrategy};
use crate::search::space::{Candidate, CandidateKey};
use crate::util::prng::Prng;

/// Proposals per batch (bounds how speculative a round can be; small
/// enough that observations steer nothing — there is nothing to steer —
/// but repeats stay cheap to reject).
const BATCH: usize = 8;
/// Rejection attempts per accepted draw.
const TRIES: usize = 64;

pub struct RandomSample {
    prng: Prng,
    proposed: HashSet<CandidateKey>,
}

impl RandomSample {
    pub fn new(seed: u64) -> Self {
        RandomSample { prng: Prng::new(seed), proposed: HashSet::new() }
    }
}

impl SearchStrategy for RandomSample {
    fn name(&self) -> &'static str {
        "random"
    }

    fn propose(&mut self, ctx: &SearchCtx<'_>, limit: usize) -> Result<Vec<Candidate>> {
        let mut batch = Vec::new();
        for _ in 0..limit.min(BATCH) {
            let mut pick = ctx.space.sample(&mut self.prng);
            for _ in 0..TRIES {
                let key = ctx.space.key(&pick);
                if !self.proposed.contains(&key) && !ctx.evaluated.contains_key(&key) {
                    break;
                }
                pick = ctx.space.sample(&mut self.prng);
            }
            self.proposed.insert(ctx.space.key(&pick));
            batch.push(pick);
        }
        Ok(batch)
    }

    fn observe(&mut self, _ctx: &SearchCtx<'_>, _batch: &[Observation]) {}
}
