//! Shuffled mini-batch iterator over a dataset's train split.

use crate::data::synth::Dataset;
use crate::error::Result;
use crate::runtime::HostTensor;
use crate::util::Prng;

/// Epoch-shuffling batcher producing fixed-size (x, y) tensors.
pub struct Batcher<'a> {
    data: &'a Dataset,
    batch: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Prng,
    // reusable staging buffers (hot path: no per-batch allocation)
    xs: Vec<f32>,
    ys: Vec<i32>,
}

impl<'a> Batcher<'a> {
    pub fn new(data: &'a Dataset, batch: usize, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let mut order: Vec<usize> = (0..data.spec.n_train).collect();
        rng.shuffle(&mut order);
        let feat = data.feat();
        Batcher {
            data,
            batch,
            order,
            cursor: 0,
            rng,
            xs: Vec::with_capacity(batch * feat),
            ys: Vec::with_capacity(batch),
        }
    }

    /// Batches consumed so far (monotonic across epochs).
    pub fn steps_per_epoch(&self) -> usize {
        self.data.spec.n_train / self.batch
    }

    /// Next fixed-size batch; reshuffles when the epoch is exhausted.
    pub fn next_batch(&mut self) -> Result<(HostTensor, HostTensor)> {
        let feat = self.data.feat();
        if self.cursor + self.batch > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
        }
        self.xs.clear();
        self.ys.clear();
        for i in 0..self.batch {
            let src = self.order[self.cursor + i];
            self.xs
                .extend_from_slice(&self.data.train_x[src * feat..(src + 1) * feat]);
            self.ys.push(self.data.train_y[src]);
        }
        self.cursor += self.batch;
        let mut shape = vec![self.batch];
        shape.extend_from_slice(&self.data.spec.input_shape);
        Ok((
            HostTensor::from_f32(&shape, self.xs.clone())?,
            HostTensor::from_i32(&[self.batch], self.ys.clone())?,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::DatasetSpec;

    fn tiny() -> Dataset {
        Dataset::generate(&DatasetSpec {
            name: "t".into(),
            input_shape: vec![4],
            n_classes: 2,
            n_train: 16,
            n_test: 8,
            noise: 0.1,
            seed: 5,
        })
    }

    #[test]
    fn batches_have_fixed_shape() {
        let d = tiny();
        let mut b = Batcher::new(&d, 8, 0);
        for _ in 0..5 {
            let (x, y) = b.next_batch().unwrap();
            assert_eq!(x.shape(), &[8, 4]);
            assert_eq!(y.shape(), &[8]);
        }
    }

    #[test]
    fn epoch_covers_all_samples() {
        let d = tiny();
        let mut b = Batcher::new(&d, 4, 1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..b.steps_per_epoch() {
            let (x, _) = b.next_batch().unwrap();
            // fingerprint rows by first feature value
            for row in 0..4 {
                seen.insert(x.as_f32().unwrap()[row * 4].to_bits());
            }
        }
        assert_eq!(seen.len(), 16);
    }
}
