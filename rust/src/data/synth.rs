//! Deterministic synthetic classification datasets.
//!
//! Generator design: class prototypes are random unit-ish vectors in
//! feature space; samples are prototype + structured nonlinearity + noise.
//! The nonlinear mixing (quadratic cross-terms) ensures a linear model
//! can't saturate the task, so network capacity matters — which is what
//! makes the pruning/scaling knees of Figs 3–5 visible.
//!
//! Image datasets place class-dependent oriented blobs on the canvas so
//! conv layers have genuine spatial structure to exploit.

use crate::error::Result;
use crate::runtime::HostTensor;
use crate::util::Prng;

/// Which synthetic dataset to generate for a model family.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetSpec {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub n_classes: usize,
    pub n_train: usize,
    pub n_test: usize,
    pub noise: f64,
    pub seed: u64,
}

impl DatasetSpec {
    /// The spec used for a manifest model family (paper §V-A mapping).
    pub fn for_model(model: &str, input_shape: &[usize], n_classes: usize) -> Self {
        match model {
            // Jet-HLF substitute: 16 high-level features, 5 jet classes.
            "jet_dnn" => DatasetSpec {
                name: "jet_hlf_sim".into(),
                input_shape: input_shape.to_vec(),
                n_classes,
                n_train: 4096,
                n_test: 2048,
                noise: 1.15,
                seed: 0x4a45_5453,
            },
            // MNIST substitute for VGG7.
            "vgg7_mini" => DatasetSpec {
                name: "mnist_sim".into(),
                input_shape: input_shape.to_vec(),
                n_classes,
                n_train: 2048,
                n_test: 1024,
                noise: 0.55,
                seed: 0x4d4e_4953,
            },
            // SVHN substitute for ResNet9.
            "resnet9_mini" => DatasetSpec {
                name: "svhn_sim".into(),
                input_shape: input_shape.to_vec(),
                n_classes,
                n_train: 2048,
                n_test: 1024,
                noise: 0.75,
                seed: 0x5356_484e,
            },
            _ => DatasetSpec {
                name: format!("{model}_sim"),
                input_shape: input_shape.to_vec(),
                n_classes,
                n_train: 2048,
                n_test: 1024,
                noise: 0.7,
                seed: 1,
            },
        }
    }
}

/// A fully materialized dataset (train + test splits).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub spec: DatasetSpec,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub test_x: Vec<f32>,
    pub test_y: Vec<i32>,
}

impl Dataset {
    pub fn generate(spec: &DatasetSpec) -> Dataset {
        let mut rng = Prng::new(spec.seed);
        let feat: usize = spec.input_shape.iter().product();
        let is_image = spec.input_shape.len() == 3;

        // Class prototypes & per-class quadratic mixers.
        let protos: Vec<Vec<f64>> = (0..spec.n_classes)
            .map(|_| (0..feat).map(|_| rng.normal()).collect())
            .collect();
        // A fixed sparse set of quadratic cross-term indices per class.
        let n_cross = (feat / 2).max(4);
        let crosses: Vec<Vec<(usize, usize, f64)>> = (0..spec.n_classes)
            .map(|_| {
                (0..n_cross)
                    .map(|_| (rng.below(feat), rng.below(feat), rng.normal()))
                    .collect()
            })
            .collect();

        let gen_split = |n: usize, rng: &mut Prng| {
            let mut xs = Vec::with_capacity(n * feat);
            let mut ys = Vec::with_capacity(n);
            for i in 0..n {
                let class = i % spec.n_classes;
                ys.push(class as i32);
                let mut x: Vec<f64> = if is_image {
                    Self::image_sample(&spec.input_shape, class, spec.n_classes, rng)
                } else {
                    // latent 2-vector drives the nonlinearity
                    let (a, b) = (rng.normal(), rng.normal());
                    (0..feat)
                        .map(|j| {
                            0.55 * protos[class][j]
                                + 0.3 * a * protos[(class + 1) % spec.n_classes][j]
                                + 0.15 * b
                        })
                        .collect()
                };
                // quadratic class-specific structure
                for &(i1, i2, w) in &crosses[class] {
                    let v = 0.12 * w * x[i1] * x[i2];
                    let j = (i1 + i2) % feat;
                    x[j] += v;
                }
                for v in x.iter_mut() {
                    *v += spec.noise * rng.normal();
                }
                xs.extend(x.iter().map(|&v| v as f32));
            }
            (xs, ys)
        };

        let (train_x, train_y) = gen_split(spec.n_train, &mut rng);
        let (test_x, test_y) = gen_split(spec.n_test, &mut rng);
        Dataset { spec: spec.clone(), train_x, train_y, test_x, test_y }
    }

    /// Class-dependent oriented blob image in [H, W, C] row-major.
    fn image_sample(shape: &[usize], class: usize, n_classes: usize, rng: &mut Prng) -> Vec<f64> {
        let (h, w, c) = (shape[0], shape[1], shape[2]);
        let mut img = vec![0.0f64; h * w * c];
        // blob center and orientation determined by class, jittered per sample
        let angle = class as f64 / n_classes as f64 * std::f64::consts::PI
            + 0.15 * rng.normal();
        let cx = w as f64 * (0.35 + 0.3 * ((class * 7 % n_classes) as f64 / n_classes as f64))
            + rng.normal();
        let cy = h as f64 * (0.35 + 0.3 * ((class * 3 % n_classes) as f64 / n_classes as f64))
            + rng.normal();
        let (dx, dy) = (angle.cos(), angle.sin());
        let len = 0.32 * h.min(w) as f64;
        let width = 1.1 + 0.25 * (class % 3) as f64;
        for y in 0..h {
            for x in 0..w {
                // distance to the oriented segment through (cx, cy)
                let px = x as f64 - cx;
                let py = y as f64 - cy;
                let along = (px * dx + py * dy).clamp(-len, len);
                let qx = px - along * dx;
                let qy = py - along * dy;
                let d2 = qx * qx + qy * qy;
                let intensity = (-d2 / (2.0 * width * width)).exp();
                for ch in 0..c {
                    // channels get class-dependent gains (SVHN-ish color cue)
                    let gain = 0.6
                        + 0.4 * (((class + ch * 3) % n_classes) as f64 / n_classes as f64);
                    img[(y * w + x) * c + ch] = 2.2 * gain * intensity;
                }
            }
        }
        img
    }

    pub fn feat(&self) -> usize {
        self.spec.input_shape.iter().product()
    }

    /// Test split as eval-sized batch tensors (pads the tail by repeating).
    pub fn test_batches(&self, batch: usize) -> Result<Vec<(HostTensor, HostTensor, usize)>> {
        let feat = self.feat();
        let n = self.spec.n_test;
        let mut out = Vec::new();
        let mut start = 0;
        while start < n {
            let end = (start + batch).min(n);
            let valid = end - start;
            let mut xs = Vec::with_capacity(batch * feat);
            let mut ys = Vec::with_capacity(batch);
            for i in 0..batch {
                let src = if i < valid { start + i } else { start + (i % valid) };
                xs.extend_from_slice(&self.test_x[src * feat..(src + 1) * feat]);
                ys.push(self.test_y[src]);
            }
            let mut shape = vec![batch];
            shape.extend_from_slice(&self.spec.input_shape);
            out.push((
                HostTensor::from_f32(&shape, xs)?,
                HostTensor::from_i32(&[batch], ys)?,
                valid,
            ));
            start = end;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let spec = DatasetSpec::for_model("jet_dnn", &[16], 5);
        let a = Dataset::generate(&spec);
        let b = Dataset::generate(&spec);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.test_y, b.test_y);
    }

    #[test]
    fn shapes_and_label_range() {
        let spec = DatasetSpec::for_model("jet_dnn", &[16], 5);
        let d = Dataset::generate(&spec);
        assert_eq!(d.train_x.len(), spec.n_train * 16);
        assert_eq!(d.train_y.len(), spec.n_train);
        assert!(d.train_y.iter().all(|&y| (0..5).contains(&y)));
        // classes balanced
        for c in 0..5 {
            let n = d.train_y.iter().filter(|&&y| y == c).count();
            assert!(n >= spec.n_train / 5 - 1);
        }
    }

    #[test]
    fn image_dataset_has_spatial_structure() {
        let spec = DatasetSpec::for_model("vgg7_mini", &[12, 12, 1], 10);
        let d = Dataset::generate(&spec);
        // same-class images must correlate more than cross-class ones
        let feat = d.feat();
        let img = |i: usize| &d.train_x[i * feat..(i + 1) * feat];
        let corr = |a: &[f32], b: &[f32]| {
            let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
            let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
            let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
            dot / (na * nb + 1e-9)
        };
        // samples i and i+n_classes share a class; i and i+1 do not
        let same = corr(img(0), img(10));
        let diff = corr(img(0), img(1));
        assert!(same > diff, "same {same} diff {diff}");
    }

    #[test]
    fn test_batches_cover_and_pad() {
        let spec = DatasetSpec {
            name: "t".into(),
            input_shape: vec![4],
            n_classes: 3,
            n_train: 10,
            n_test: 10,
            noise: 0.5,
            seed: 3,
        };
        let d = Dataset::generate(&spec);
        let batches = d.test_batches(4).unwrap();
        assert_eq!(batches.len(), 3); // 4 + 4 + 2(padded to 4)
        assert_eq!(batches[2].2, 2);
        let total: usize = batches.iter().map(|b| b.2).sum();
        assert_eq!(total, 10);
        for (x, y, _) in &batches {
            assert_eq!(x.shape(), &[4, 4]);
            assert_eq!(y.shape(), &[4]);
        }
    }
}
