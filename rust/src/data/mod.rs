//! Synthetic dataset substrate.
//!
//! The paper trains on Jet-HLF (CERN LHC jet tagging), MNIST and SVHN —
//! none of which are available offline.  Per the substitution rule
//! (DESIGN.md §1) we synthesize datasets with matched *shape* and tuned
//! difficulty: what the paper's experiments measure is the accuracy-vs-
//! pruning/quantization/scaling tradeoff, which only requires a task that
//! (a) a scaled/pruned model can still learn and (b) degrades smoothly as
//! capacity is removed.

pub mod batcher;
pub mod synth;

pub use batcher::Batcher;
pub use synth::{Dataset, DatasetSpec};
