//! # MetaML
//!
//! Reproduction of *MetaML: Automating Customizable Cross-Stage Design-Flow
//! for Deep Learning Acceleration* (Que et al., FPL 2023) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! MetaML builds **design flows** — cyclic directed graphs of reusable
//! [pipe tasks](flow::PipeTask) — that co-optimize a DNN and its hardware
//! mapping across abstraction levels:
//!
//! * **O-tasks** optimize a model: [tasks::PruningTask] (auto binary-search
//!   magnitude pruning), [tasks::ScalingTask] (layer-width search),
//!   [tasks::QuantizationTask] (HLS-level mixed-precision walk) in the DNN
//!   stage, and [tasks::ReuseSearchTask] (per-layer reuse-factor search
//!   against the synthesis estimator) in the FPGA stage;
//! * **λ-tasks** transform between abstractions: [tasks::ModelGenTask]
//!   (train a DNN via the PJRT runtime), [tasks::Hls4mlTask] (DNN → HLS
//!   C++ model), [tasks::VivadoHlsTask] (HLS → RTL resource/latency report).
//!
//! Tasks communicate through the [metamodel::MetaModel]: a CFG key-value
//! store, a LOG execution trace, and a model space holding DNN / HLS / RTL
//! abstractions.
//!
//! The compute hot path (training / evaluating candidate models) runs
//! through the pluggable [runtime::ExecBackend] trait, decoupling
//! design-flow tasks from the execution substrate:
//!
//! * the default [runtime::RefBackend] is a pure-Rust reference
//!   interpreter of the train/eval step semantics (masked + fake-quantized
//!   matmuls, softmax cross-entropy SGD) — zero native dependencies, so
//!   every flow runs on any machine;
//! * with `--features xla`, the PJRT backend (`runtime::PjrtBackend`)
//!   executes AOT-compiled XLA artifacts produced once by
//!   `python/compile/aot.py` from JAX models whose inner loops are Pallas
//!   kernels — Python never runs at flow-execution time.
//!
//! The substrate is `Send + Sync` end to end, and the O-task searches
//! fan their candidate probes out across the [dse::ProbePool] — a
//! scoped-thread worker pool generic over probe kinds (training probes
//! through the trainer, hardware probes through the synthesis
//! estimator), each with a memoizing cache that keeps results
//! bit-identical to sequential execution (see [dse]).
//!
//! The flow layer is a composable IR: specs declare conditional edges
//! (guards over meta-model metrics), strategy (S-task) nodes selecting
//! among child flows at runtime, and embedded sub-flows; the engine is
//! a small control-flow VM logging every branch decision, and
//! [flow::explore] runs whole *flow-architecture* grids concurrently,
//! reporting a deterministic (accuracy, DSP, LUT, latency) Pareto front.
//!
//! On top of the explorer sits the budgeted [search] subsystem:
//! pluggable multi-objective [search::SearchStrategy] implementations
//! (`exhaustive`, seeded `random`, NSGA-II-style `evolve` with an
//! optional hardware-estimator prefilter) that pick *which* variants of
//! the joint (orders × grid × numeric ranges) space to evaluate under
//! an explicit evaluation budget, reusing the same probe pools and
//! shared memos so results stay deterministic and jobs-invariant.
//!
//! Every layer reports into the strictly side-band [obs] subsystem —
//! structured spans (flow tasks/edges, search rounds, the probe
//! lifecycle, cache tiers, opt-in kernels) plus an always-on metrics
//! registry — exported as Chrome trace-event JSON / metric snapshots
//! without perturbing any determinism contract.

pub mod baselines;
pub mod bench_support;
pub mod config;
pub mod data;
pub mod dse;
pub mod error;
pub mod flow;
pub mod hls;
pub mod json;
pub mod metamodel;
pub mod model;
pub mod obs;
pub mod prune;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod scale;
pub mod search;
pub mod synth;
pub mod tasks;
pub mod testutil;
pub mod train;
pub mod util;

pub use error::{Error, Result};

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
